"""Query engine over the dual index — every representative query from
paper Table I, as vectorized predicates on the primary index plus direct
lookups on the aggregate index.

This is the programmatic surface the paper's web interface (graphical
query builder / raw regex mode / summary templates) sits on.

The engine is index-shape agnostic: ``primary`` may be the monolithic
``PrimaryIndex`` or a ``sharded_index.ShardedPrimaryIndex``. Scans read
the schema-stable ``live()`` view — on a sharded primary that is a
scatter-gather (per-shard views fanned out and merged); point lookups
(``stat``) route to the single owning shard (DESIGN.md §8).

Consistency semantics (paper §V-C; DESIGN.md §6.3): each query reads a
``live()`` view materialized at call time, so one query is internally
consistent — it never mixes a record's pre- and post-update columns. Two
successive queries may straddle an event-ingest apply and disagree;
callers that care attach the freshness watermark via ``freshness()`` /
``query()``, which reports the changelog seq the read data reflects and
how many events are still buffered behind it (nonzero only in the
ingestor's ``buffered`` mode).
"""
from __future__ import annotations

import fnmatch
import re
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import discovery as disc
from repro.core.index import AggregateIndex, PrimaryIndex


def resolve_now(now) -> float:
    """One clock-resolution rule for every ``now`` knob (QueryEngine,
    the dashboard renderers): None reads ``time.time`` at call time, a
    float pins a deterministic clock, a callable supplies your own."""
    if now is None:
        return time.time()
    return float(now()) if callable(now) else float(now)


def merge_freshness(marks: Sequence[Dict[str, float]]
                    ) -> Optional[Dict[str, float]]:
    """Combine per-partition watermarks into the deployment-wide one: a
    reader is only as fresh as its STALEST partition, so ``applied_seq``
    is the min over sources, ``staleness_s`` the max, and pending events
    sum (paper §IV-B4: one monitor/ingestor per MDT or index shard)."""
    marks = [m for m in marks if m]
    if not marks:
        return None
    return {
        "mode": "+".join(sorted({str(m.get("mode")) for m in marks})),
        "applied_seq": min(m["applied_seq"] for m in marks),
        "pending_events": sum(m["pending_events"] for m in marks),
        "staleness_s": max(m["staleness_s"] for m in marks),
        "applied_batches": sum(m.get("applied_batches", 0) for m in marks),
        # a deployment is only as reconciled as its LEAST-recently
        # reconciled partition (0.0 = some partition never was)
        "reconciled_at": min(m.get("reconciled_at", 0.0) for m in marks),
        # uncommitted events still sitting in the durable log sum across
        # partitions, like pending_events (DESIGN.md §10.4; 0 on
        # direct-fed ingestors or marks predating the pipeline)
        "log_lag": sum(m.get("log_lag", 0) for m in marks),
        # primary mutations not yet reflected in queryable discovery
        # state (DESIGN.md §11.3; 0 = accelerated queries are exact,
        # also 0 when no discovery index is attached)
        "index_lag": sum(m.get("index_lag", 0) for m in marks),
        "sources": len(marks),
    }


class QueryEngine:
    def __init__(self, primary: PrimaryIndex, aggregate: AggregateIndex,
                 now=None, ingestor=None):
        """``ingestor``: optional event_ingest.EventIngestor (duck-typed —
        anything with ``freshness()``) whose watermark stamps results. A
        list/tuple of ingestors (e.g. one per MDT feeding a sharded
        primary) min-merges into one watermark via merge_freshness.

        ``now``: the clock the time-window predicates
        (``not_accessed_since`` / ``large_cold_files`` /
        ``past_retention``) evaluate against. Default None means
        ``time.time`` read PER QUERY — a long-lived engine must not
        freeze its notion of "now" at construction, or cold-data windows
        silently drift stale. Pass a float to pin a deterministic clock
        (tests, replaying historical scans) or any callable to supply
        your own."""
        self.primary = primary
        self.aggregate = aggregate
        self._now = time.time if now is None else now
        self.ingestor = ingestor
        # per-thread plan records: concurrent readers sharing one
        # engine (the serving tier admits N at once) must not observe
        # each other's routing decisions
        self._plan_tls = threading.local()

    @property
    def now(self) -> float:
        """The query clock: re-read per access when callable-backed."""
        return resolve_now(self._now)

    @now.setter
    def now(self, value) -> None:
        self._now = value

    # -- freshness (paper's consistency/latency/freshness knobs) --------------

    def freshness(self) -> Optional[Dict[str, float]]:
        """Watermark of the data this engine reads: highest applied
        changelog seq, pending (buffered, not yet visible) events, and
        staleness seconds. None when no event ingestor is attached
        (pure-snapshot deployments). Multiple ingestors min-merge —
        freshness is the min watermark over partitions."""
        if self.ingestor is None:
            return None
        if isinstance(self.ingestor, (list, tuple)):
            return merge_freshness([i.freshness() for i in self.ingestor])
        return self.ingestor.freshness()

    #: the ONLY names ``query()`` dispatches — the web interface's raw
    #: query surface must not reach arbitrary attributes (``now``,
    #: private helpers, the index objects themselves)
    QUERY_METHODS = frozenset({
        "stat", "find_by_name", "find_by_glob", "world_writable",
        "not_accessed_since", "large_cold_files", "duplicate_candidates",
        "owned_by_deleted_users", "past_retention", "directories_over",
        "storage_by_project", "quota_pressure", "most_small_files",
        "per_user_usage", "dir_size_percentile", "top_storage_users",
    })

    def query(self, name: str, *args, **kw) -> Dict:
        """Run a named query and stamp the result with the freshness
        watermark it was read at — the shape the paper's web interface
        returns ({"result": ..., "freshness": {...}}). ``name`` must be
        in ``QUERY_METHODS`` (raw web-interface input must not dispatch
        to arbitrary engine attributes)."""
        if name not in self.QUERY_METHODS:
            raise ValueError(
                f"unknown query {name!r}; expected one of "
                f"{sorted(self.QUERY_METHODS)}")
        fn = getattr(self, name)
        return {"result": fn(*args, **kw), "freshness": self.freshness()}

    # -- the discovery-index planner (DESIGN.md §11.3) ------------------------
    #
    # Each selective primary-index query below first asks the planner
    # for an accelerated answer: candidate prefilter through the
    # discovery index's sorted runs / trigram postings, exact verify
    # against the primary arenas. The planner routes to the index ONLY
    # when every shard's discovery index is attached and fresh;
    # otherwise it transparently falls back to the scan path. Either
    # route returns byte-identical results (tests/test_discovery.py
    # pins this property across corpora, delta fill, staleness, and
    # shard counts). ``last_plan`` records the routing decision.

    @property
    def last_plan(self) -> Optional[Dict]:
        """Routing record of THIS THREAD's most recent plannable query:
        {"query", "route": "discovery"|"scan", "reason", "candidates"}.
        Thread-local — it used to be a shared attribute, so two
        interleaved planner queries read each other's plans
        (tests/test_query_service.py pins the regression)."""
        return getattr(self._plan_tls, "plan", None)

    @last_plan.setter
    def last_plan(self, value: Optional[Dict]) -> None:
        self._plan_tls.plan = value

    def _discovery_route(self):
        """(shard discovery list, reason) — list is None on fallback."""
        ds = disc.discovery_shards(self.primary)
        if ds is None:
            return None, "no discovery index attached"
        if not all(d.fresh for d in ds):
            return None, "discovery index stale (pending rebuild)"
        return ds, "fresh"

    def _plan(self, qname: str, shard_query) -> Optional[np.ndarray]:
        """Common planner tail: route check, per-shard fan-out +
        shard-order merge (== the scan's shard-major row order), and
        the ``last_plan`` record. None -> caller scans."""
        ds, reason = self._discovery_route()
        if ds is None:
            self.last_plan = {"query": qname, "route": "scan",
                              "reason": reason}
            return None
        parts = [shard_query(d) for d in ds]
        self.last_plan = {
            "query": qname, "route": "discovery", "reason": reason,
            "candidates": sum(d.stats.get("last_candidates", 0)
                              for d in ds)}
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _plan_select(self, qname: str,
                     preds: Sequence[Tuple[str, str, object]]
                     ) -> Optional[np.ndarray]:
        """Accelerated predicate query, or None -> caller scans."""
        return self._plan(qname, lambda d: d.select(preds))

    def _plan_names(self, qname: str, literals: Sequence[str],
                    match) -> Optional[np.ndarray]:
        """Accelerated name query: trigram candidates from the
        literals guaranteed in any match, verified by ``match`` (the
        exact compiled matcher). None -> caller scans (no usable
        literal, or discovery unavailable/stale)."""
        codes = disc.literal_trigrams(literals)
        if not codes:
            self.last_plan = {"query": qname, "route": "scan",
                              "reason": "no literal >= 3 bytes in pattern"}
            return None
        return self._plan(qname, lambda d: d.name_select(codes, match))

    # -- individual-granularity queries (primary index) ----------------------

    def stat(self, path: str) -> Optional[Dict]:
        """Point lookup by exact subject: one slot-map probe — on a
        sharded primary this routes to the single owning shard, no
        scatter (DESIGN.md §8)."""
        return self.primary.lookup(path)

    def find_by_name(self, pattern: str) -> np.ndarray:
        """name LIKE "*pattern*" (regex-match raw mode). Planner: the
        literals guaranteed in any match prefilter through the trigram
        index; each candidate is verified with the real compiled regex,
        so results are byte-identical to the scan. Fallback (stale
        index / no >=3-byte literal): scan the path-only live view
        (``live_paths``) — no full-column materialization — with the
        regex compiled once and its bound ``search`` applied in a
        single comprehension pass."""
        search = re.compile(pattern).search
        got = self._plan_names("find_by_name", disc.regex_literals(pattern),
                               lambda p: search(p) is not None)
        if got is not None:
            return got
        paths = self.primary.live_paths()
        return paths[[i for i, p in enumerate(paths) if search(p)]]

    def find_by_glob(self, pattern: str) -> np.ndarray:
        """name LIKE a shell glob (the web interface's non-regex search
        box). Same planner/fallback split as ``find_by_name``, with
        ``fnmatch.fnmatchcase`` as the exact verifier."""
        got = self._plan_names(
            "find_by_glob", disc.glob_literals(pattern),
            lambda p: fnmatch.fnmatchcase(p, pattern))
        if got is not None:
            return got
        paths = self.primary.live_paths()
        return paths[[i for i, p in enumerate(paths)
                      if fnmatch.fnmatchcase(p, pattern)]]

    def world_writable(self) -> np.ndarray:
        """Table I "world-writable files" (security audit): mode & 0o002.
        Planner: mode-run sweep + exact verify; fallback reads the
        live() snapshot of the primary index."""
        got = self._plan_select("world_writable", [("mode", "mask", 0o002)])
        if got is not None:
            return got
        live = self.primary.live()
        return live["path"][(live["mode"] & 0o002) != 0]

    def not_accessed_since(self, seconds: float) -> np.ndarray:
        """Table I "not accessed in N months" (cold-data candidates)."""
        cutoff = self.now - seconds
        got = self._plan_select("not_accessed_since",
                                [("atime", "lt", cutoff)])
        if got is not None:
            return got
        live = self.primary.live()
        return live["path"][live["atime"] < cutoff]

    def large_cold_files(self, min_size: float, idle_seconds: float) -> np.ndarray:
        """Table I "large files with low access" (tiering candidates)."""
        cutoff = self.now - idle_seconds
        got = self._plan_select("large_cold_files",
                                [("size", "gt", min_size),
                                 ("atime", "lt", cutoff)])
        if got is not None:
            return got
        live = self.primary.live()
        m = (live["size"] > min_size) & (live["atime"] < cutoff)
        return live["path"][m]

    def duplicate_candidates(self) -> Dict[int, np.ndarray]:
        """GROUP BY checksum HAVING count > 1 (``path_hash`` as the
        stand-in checksum column), keyed by the hash value. Same-size
        files with different hashes are NOT candidates — grouping by
        ``size`` here was a bug that flooded the report on any corpus
        with repeated sizes."""
        live = self.primary.live()
        hashes = live["path_hash"].astype(np.int64)
        uniq, inv, counts = np.unique(hashes, return_inverse=True,
                                      return_counts=True)
        out = {}
        for ui in np.nonzero(counts > 1)[0]:
            out[int(uniq[ui])] = live["path"][inv == ui]
        return out

    def owned_by_deleted_users(self, active_uids: Sequence[int]) -> np.ndarray:
        """Table I "files owned by deleted users" (orphan sweep)."""
        uids = list(active_uids)
        got = self._plan_select("owned_by_deleted_users",
                                [("uid", "notin", uids)])
        if got is not None:
            return got
        live = self.primary.live()
        return live["path"][~np.isin(live["uid"], uids)]

    def past_retention(self, retention_seconds: float) -> np.ndarray:
        """Table I "past retention policy" (purge candidates)."""
        cutoff = self.now - retention_seconds
        got = self._plan_select("past_retention", [("mtime", "lt", cutoff)])
        if got is not None:
            return got
        live = self.primary.live()
        return live["path"][live["mtime"] < cutoff]

    # -- aggregate-granularity queries (aggregate index) ----------------------

    def directories_over(self, n_files: float) -> List[str]:
        """Table I "directories with > N entries". Aggregate-index read:
        per-principal records are whole (never half-written), but may
        trail the primary index by one apply (DESIGN.md §6.3)."""
        return [p for p, c in self.aggregate.records.items()
                if p.startswith("dir:") and c["file_count"] > n_files]

    def storage_by_project(self) -> Dict[str, float]:
        """SUM(size) GROUP BY project — projects are groups here."""
        return {p: c["size"]["total"] for p, c in self.aggregate.records.items()
                if p.startswith("group:")}

    def quota_pressure(self, quotas: Dict[str, float], thresh: float = 0.9
                       ) -> List[Tuple[str, float]]:
        """Table I "principals near quota": total size / quota > thresh."""
        out = []
        for p, c in self.aggregate.records.items():
            q = quotas.get(p)
            if q and c["size"]["total"] / q > thresh:
                out.append((p, c["size"]["total"] / q))
        return out

    def most_small_files(self, k: int = 10) -> List[Tuple[str, float]]:
        """COUNT(file_size < 1MB) DESC per user — estimated from each
        user's size-sketch CDF at 1 MB (sketch-powered semantic query)."""
        live = self.primary.live()
        # exact path for validation:
        users, counts = np.unique(live["uid"][live["size"] < 1e6],
                                  return_counts=True)
        order = np.argsort(-counts)
        return [(f"user:{int(users[i])}", float(counts[i]))
                for i in order[:k]]

    def per_user_usage(self) -> Dict[str, Tuple[float, float]]:
        """SUM(size), COUNT(*) GROUP BY uid."""
        return {p: (c["size"]["total"], c["file_count"])
                for p, c in self.aggregate.records.items()
                if p.startswith("user:")}

    def dir_size_percentile(self, q: str = "p99") -> Dict[str, float]:
        """PERCENTILE(size, q) for directory principals."""
        return {p: c["size"][q] for p, c in self.aggregate.records.items()
                if p.startswith("dir:")}

    def top_storage_users(self, k: int = 10) -> List[Tuple[str, float]]:
        """Table I "top storage consumers" (admin dashboard staple)."""
        items = [(p, c["size"]["total"])
                 for p, c in self.aggregate.records.items()
                 if p.startswith("user:")]
        items.sort(key=lambda x: -x[1])
        return items[:k]

    # -- the full Table I suite, timed (for bench_index_query) ----------------

    def run_table1_suite(self) -> Dict[str, float]:
        timings = {}

        def timed(name, fn, *a):
            t0 = time.perf_counter()
            fn(*a)
            timings[name] = time.perf_counter() - t0

        timed("name_like", self.find_by_name, r"f1\d\d$")
        timed("world_writable", self.world_writable)
        timed("not_accessed_12m", self.not_accessed_since, 365 * 86400)
        timed("large_low_access", self.large_cold_files, 100e9, 180 * 86400)
        timed("duplicates", self.duplicate_candidates)
        timed("dirs_over_100k", self.directories_over, 100_000)
        timed("storage_by_project", self.storage_by_project)
        timed("quota_pressure", self.quota_pressure,
              {p: 1e12 for p in self.aggregate.records}, 0.9)
        timed("deleted_users", self.owned_by_deleted_users, list(range(16)))
        timed("past_retention", self.past_retention, 2 * 365 * 86400)
        timed("most_small_files", self.most_small_files)
        timed("per_user_usage", self.per_user_usage)
        timed("dir_p99", self.dir_size_percentile)
        return timings
