"""Query engine over the dual index — every representative query from
paper Table I, as vectorized predicates on the primary index plus direct
lookups on the aggregate index.

This is the programmatic surface the paper's web interface (graphical
query builder / raw regex mode / summary templates) sits on.

The engine is index-shape agnostic: ``primary`` may be the monolithic
``PrimaryIndex`` or a ``sharded_index.ShardedPrimaryIndex``. Scans read
the schema-stable ``live()`` view — on a sharded primary that is a
scatter-gather (per-shard views fanned out and merged); point lookups
(``stat``) route to the single owning shard (DESIGN.md §8).

Consistency semantics (paper §V-C; DESIGN.md §6.3): each query reads a
``live()`` view materialized at call time, so one query is internally
consistent — it never mixes a record's pre- and post-update columns. Two
successive queries may straddle an event-ingest apply and disagree;
callers that care attach the freshness watermark via ``freshness()`` /
``query()``, which reports the changelog seq the read data reflects and
how many events are still buffered behind it (nonzero only in the
ingestor's ``buffered`` mode).
"""
from __future__ import annotations

import fnmatch
import re
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import discovery as disc
from repro.core import hierarchy as hier
from repro.core.index import AggregateIndex, PrimaryIndex
from repro.core.telemetry import resolve as _resolve_tel

_PREDEVAL = None


def _predeval():
    """Lazy handle to the fused predicate-kernel package (DESIGN.md
    §13): (ops module, ref module), or None when the package cannot
    import at all. Note jax being absent does NOT disable it — the
    package's numpy host oracle then evaluates the same programs; the
    ``use_kernels`` auto mode just declines to route there (the scan
    path is cheaper than oracle + verify on pure numpy)."""
    global _PREDEVAL
    if _PREDEVAL is None:
        try:
            from repro.kernels.predeval import ops as pk_ops
            from repro.kernels.predeval import ref as pk_ref
            _PREDEVAL = (pk_ops, pk_ref)
        except Exception:
            _PREDEVAL = False
    return _PREDEVAL or None


#: the queries the planner can express as predicate lists over the
#: primary arenas — exactly the ones with a ``_plan_select`` route
PREDICATE_QUERIES = frozenset({
    "world_writable", "not_accessed_since", "large_cold_files",
    "owned_by_deleted_users", "past_retention",
})

#: predicate queries whose cutoffs derive from the query clock: their
#: answers change with wall time even at an unchanged watermark, so
#: the serving tier folds the resolved clock into their cache keys
TIME_RELATIVE = frozenset({
    "not_accessed_since", "large_cold_files", "past_retention",
})

#: queries answered from the subtree-rollup tree (DESIGN.md §14) when
#: an exact HierarchyIndex is attached, with a brute-force scan over
#: ``live()`` as the byte-identical fallback. The serving tier folds
#: the hierarchy's apply epoch into their cache keys — their answers
#: move with structure changes the primary watermark alone may miss.
HIER_QUERIES = frozenset({
    "du", "subtree_summary", "hot_directories",
})


def _bind(args: Tuple, kw: Dict, *names: str) -> List:
    """Bind one value per parameter name from (*args, **kw), no
    defaults, no extras — TypeError mirrors what calling the query
    method itself would raise."""
    if len(args) > len(names) or set(kw) - set(names[len(args):]):
        raise TypeError("bad query arguments")
    vals = list(args)
    for nm in names[len(args):]:
        if nm not in kw:
            raise TypeError(f"missing query argument {nm!r}")
        vals.append(kw[nm])
    return vals


def pred_spec(name: str, args: Tuple, kw: Dict,
              now: float) -> Optional[List[Tuple[str, str, object]]]:
    """The predicate list a named Table-I query evaluates — the same
    tuples its method hands ``_plan_select`` — with time-relative
    cutoffs resolved against the CALLER's ``now``. None when ``name``
    is not a predicate query or the arguments do not bind (the caller
    then dispatches the method directly and lets it raise naturally).
    Shared by ``select_many`` and the serving tier's time-pinned
    execution + cache keying."""
    if name not in PREDICATE_QUERIES:
        return None
    try:
        if name == "world_writable":
            _bind(args, kw)
            return [("mode", "mask", 0o002)]
        if name == "not_accessed_since":
            (seconds,) = _bind(args, kw, "seconds")
            return [("atime", "lt", now - float(seconds))]
        if name == "large_cold_files":
            min_size, idle = _bind(args, kw, "min_size", "idle_seconds")
            return [("size", "gt", min_size),
                    ("atime", "lt", now - float(idle))]
        if name == "owned_by_deleted_users":
            (uids,) = _bind(args, kw, "active_uids")
            return [("uid", "notin", list(uids))]
        if name == "past_retention":
            (ret,) = _bind(args, kw, "retention_seconds")
            return [("mtime", "lt", now - float(ret))]
    except TypeError:
        return None
    return None


def _shard_rows(sh) -> int:
    """Rows a scan of this shard covers: ``snapshot.n`` on a pinned
    view, ``len(slot_map)`` (assigned slots) on a live index."""
    n = getattr(sh, "n", None)
    if n is not None:
        return int(n)
    return len(sh.slot_map)


def resolve_now(now) -> float:
    """One clock-resolution rule for every ``now`` knob (QueryEngine,
    the dashboard renderers): None reads ``time.time`` at call time, a
    float pins a deterministic clock, a callable supplies your own."""
    if now is None:
        return time.time()
    return float(now()) if callable(now) else float(now)


def merge_freshness(marks: Sequence[Dict[str, float]]
                    ) -> Optional[Dict[str, float]]:
    """Combine per-partition watermarks into the deployment-wide one: a
    reader is only as fresh as its STALEST partition, so ``applied_seq``
    is the min over sources, ``staleness_s`` the max, and pending events
    sum (paper §IV-B4: one monitor/ingestor per MDT or index shard)."""
    marks = [m for m in marks if m]
    if not marks:
        return None
    return {
        "mode": "+".join(sorted({str(m.get("mode")) for m in marks})),
        # the required trio defaults like every later key: a mark from a
        # layer that only exports lag fields (e.g. a policy engine or a
        # bare replication tier) must degrade the merge, not KeyError it.
        # Missing applied_seq pins the deployment watermark at 0 — the
        # conservative "I can't vouch for anything newer" reading
        "applied_seq": min(m.get("applied_seq", 0) for m in marks),
        "pending_events": sum(m.get("pending_events", 0) for m in marks),
        "staleness_s": max(m.get("staleness_s", 0.0) for m in marks),
        "applied_batches": sum(m.get("applied_batches", 0) for m in marks),
        # a deployment is only as reconciled as its LEAST-recently
        # reconciled partition (0.0 = some partition never was)
        "reconciled_at": min(m.get("reconciled_at", 0.0) for m in marks),
        # uncommitted events still sitting in the durable log sum across
        # partitions, like pending_events (DESIGN.md §10.4; 0 on
        # direct-fed ingestors or marks predating the pipeline)
        "log_lag": sum(m.get("log_lag", 0) for m in marks),
        # primary mutations not yet reflected in queryable discovery
        # state (DESIGN.md §11.3; 0 = accelerated queries are exact,
        # also 0 when no discovery index is attached)
        "index_lag": sum(m.get("index_lag", 0) for m in marks),
        # subtree-rollup health (DESIGN.md §14): deferred propagation
        # work sums across partitions; the deployment's rollup route is
        # exact only if EVERY partition's tree is (marks predating the
        # rollup layer count as inexact, forcing the scan fallback)
        "rollup_dirty": sum(m.get("rollup_dirty", 0) for m in marks),
        "rollup_exact": all(m.get("rollup_exact", False) for m in marks),
        # replicated read tier (core/replication.py, DESIGN.md §15):
        # events applied on the leader but not yet on the laggiest
        # follower — a deployment's stale-tolerant reads trail by its
        # WORST replica, so the merge takes the max (0 = no replicas or
        # all caught up; marks predating replication count as 0)
        "replica_lag": max(m.get("replica_lag", 0) for m in marks),
        "sources": len(marks),
    }


class QueryEngine:
    def __init__(self, primary: PrimaryIndex, aggregate: AggregateIndex,
                 now=None, ingestor=None,
                 use_kernels: Optional[bool] = None,
                 hierarchy=None, telemetry=None):
        """``ingestor``: optional event_ingest.EventIngestor (duck-typed —
        anything with ``freshness()``) whose watermark stamps results. A
        list/tuple of ingestors (e.g. one per MDT feeding a sharded
        primary) min-merges into one watermark via merge_freshness.

        ``now``: the clock the time-window predicates
        (``not_accessed_since`` / ``large_cold_files`` /
        ``past_retention``) evaluate against. Default None means
        ``time.time`` read PER QUERY — a long-lived engine must not
        freeze its notion of "now" at construction, or cold-data windows
        silently drift stale. Pass a float to pin a deterministic clock
        (tests, replaying historical scans) or any callable to supply
        your own.

        ``use_kernels``: route predicate queries through the fused
        predicate kernel (DESIGN.md §13) when the discovery index
        cannot serve them. None (auto) enables it when jax is
        importable; False pins the pure-numpy scan fallback; True
        forces the kernel package even without jax (its numpy host
        oracle — slower than the scan, but it exercises the fallback
        path end to end).

        ``hierarchy``: optional hierarchy.HierarchyIndex serving the
        subtree-rollup queries (``du`` / ``subtree_summary`` /
        ``hot_directories``). None auto-adopts ``ingestor.hierarchy``
        when a single ingestor is attached; without one, those queries
        fall back to the brute-force scan over ``live()``."""
        self.primary = primary
        self.aggregate = aggregate
        self._now = time.time if now is None else now
        self.ingestor = ingestor
        self.use_kernels = use_kernels
        if hierarchy is None and ingestor is not None \
                and not isinstance(ingestor, (list, tuple)):
            hierarchy = getattr(ingestor, "hierarchy", None)
        self.hierarchy = hierarchy
        #: per-(shard position) device arena cache keyed by mutation
        #: epoch + row count: {si: ((epoch, n), Arena)}. Entries for a
        #: pinned snapshot engine never churn; on a live engine each
        #: mutation batch invalidates by key mismatch. Plain dict ops
        #: are atomic under the GIL — concurrent readers at worst
        #: rebuild the same immutable slab twice.
        self._arena_cache: Dict[int, Tuple] = {}
        # per-thread plan records: concurrent readers sharing one
        # engine (the serving tier admits N at once) must not observe
        # each other's routing decisions
        self._plan_tls = threading.local()
        # route-cascade instruments, families bound once (labels() on a
        # hot path is one dict hit)
        self.telemetry = _resolve_tel(telemetry)
        self._h_route_s = self.telemetry.histogram(
            "query_route_seconds",
            "predicate-query latency by chosen route",
            labels=("route",))
        self._c_fallback = self.telemetry.counter(
            "query_discovery_fallback_total",
            "planner declines by reason",
            labels=("reason",))

    @property
    def now(self) -> float:
        """The query clock: re-read per access when callable-backed."""
        return resolve_now(self._now)

    @now.setter
    def now(self, value) -> None:
        self._now = value

    # -- freshness (paper's consistency/latency/freshness knobs) --------------

    def freshness(self) -> Optional[Dict[str, float]]:
        """Watermark of the data this engine reads: highest applied
        changelog seq, pending (buffered, not yet visible) events, and
        staleness seconds. None when no event ingestor is attached
        (pure-snapshot deployments). Multiple ingestors min-merge —
        freshness is the min watermark over partitions."""
        if self.ingestor is None:
            return None
        if isinstance(self.ingestor, (list, tuple)):
            return merge_freshness([i.freshness() for i in self.ingestor])
        return self.ingestor.freshness()

    #: the ONLY names ``query()`` dispatches — the web interface's raw
    #: query surface must not reach arbitrary attributes (``now``,
    #: private helpers, the index objects themselves)
    QUERY_METHODS = frozenset({
        "stat", "find_by_name", "find_by_glob", "world_writable",
        "not_accessed_since", "large_cold_files", "duplicate_candidates",
        "owned_by_deleted_users", "past_retention", "directories_over",
        "storage_by_project", "quota_pressure", "most_small_files",
        "per_user_usage", "dir_size_percentile", "top_storage_users",
        "du", "subtree_summary", "hot_directories",
    })

    def query(self, name: str, *args, **kw) -> Dict:
        """Run a named query and stamp the result with the freshness
        watermark it was read at — the shape the paper's web interface
        returns ({"result": ..., "freshness": {...}}). ``name`` must be
        in ``QUERY_METHODS`` (raw web-interface input must not dispatch
        to arbitrary engine attributes)."""
        if name not in self.QUERY_METHODS:
            raise ValueError(
                f"unknown query {name!r}; expected one of "
                f"{sorted(self.QUERY_METHODS)}")
        fn = getattr(self, name)
        return {"result": fn(*args, **kw), "freshness": self.freshness()}

    # -- the discovery-index planner (DESIGN.md §11.3) ------------------------
    #
    # Each selective primary-index query below first asks the planner
    # for an accelerated answer: candidate prefilter through the
    # discovery index's sorted runs / trigram postings, exact verify
    # against the primary arenas. The planner routes to the index ONLY
    # when every shard's discovery index is attached and fresh;
    # otherwise it transparently falls back to the scan path. Either
    # route returns byte-identical results (tests/test_discovery.py
    # pins this property across corpora, delta fill, staleness, and
    # shard counts). ``last_plan`` records the routing decision.

    @property
    def last_plan(self) -> Optional[Dict]:
        """Routing record of THIS THREAD's most recent plannable query:
        {"query", "route": "discovery"|"scan", "reason", "candidates"}.
        Thread-local — it used to be a shared attribute, so two
        interleaved planner queries read each other's plans
        (tests/test_query_service.py pins the regression)."""
        return getattr(self._plan_tls, "plan", None)

    @last_plan.setter
    def last_plan(self, value: Optional[Dict]) -> None:
        self._plan_tls.plan = value

    def _discovery_route(self):
        """(shard discovery list, reason) — list is None on fallback."""
        ds = disc.discovery_shards(self.primary)
        if ds is None:
            self._c_fallback.labels("unattached").inc()
            return None, "no discovery index attached"
        if not all(d.fresh for d in ds):
            self._c_fallback.labels("stale").inc()
            return None, "discovery index stale (pending rebuild)"
        return ds, "fresh"

    def _plan(self, qname: str, shard_query) -> Optional[np.ndarray]:
        """Common planner tail: route check, per-shard fan-out +
        shard-order merge (== the scan's shard-major row order), and
        the ``last_plan`` record. None -> caller scans."""
        ds, reason = self._discovery_route()
        if ds is None:
            self.last_plan = {"query": qname, "route": "scan",
                              "reason": reason}
            return None
        parts = [shard_query(d) for d in ds]
        self.last_plan = {
            "query": qname, "route": "discovery", "reason": reason,
            "candidates": sum(d.stats.get("last_candidates", 0)
                              for d in ds)}
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _plan_select(self, qname: str,
                     preds: Sequence[Tuple[str, str, object]]
                     ) -> Optional[np.ndarray]:
        """Accelerated predicate query, or None -> caller scans. Route
        order: discovery index (attached + fresh) -> fused predicate
        kernel (enabled + program expressible) -> numpy scan."""
        got = self._plan(qname, lambda d: d.select(preds))
        if got is not None:
            return got
        return self._kernel_select(qname, preds)

    # -- the fused predicate-kernel route (DESIGN.md §13) ---------------------

    def _kernels_enabled(self) -> bool:
        if self.use_kernels is False:
            return False
        pk = _predeval()
        if pk is None:
            return False
        # auto mode: without jax the kernel package only offers the
        # numpy host oracle, which a direct scan beats — decline
        return bool(self.use_kernels) or pk[0].AVAILABLE

    def _index_shards(self) -> List:
        """The physical shards a scan walks, in scan (shard-major)
        order — PrimaryIndex / IndexSnapshot duck-typed alike."""
        shards = getattr(self.primary, "shards", None)
        return list(shards) if shards is not None else [self.primary]

    def _shard_arena(self, si: int, sh, n: int):
        """The (shard, epoch) device arena slab, cached per shard
        position; a mutation-epoch or row-count change rebuilds."""
        pk_ops, _ = _predeval()
        key = (int(sh.mutation_epoch), n)
        hit = self._arena_cache.get(si)
        if hit is not None and hit[0] == key:
            return hit[1]
        arena = pk_ops.pack_arena(sh.columns, sh.alive, n)
        self._arena_cache[si] = (key, arena)
        return arena

    def _kernel_select(self, qname: str,
                       preds: Sequence[Tuple[str, str, object]]
                       ) -> Optional[np.ndarray]:
        """One fused kernel pass per shard: compile the predicate list
        into a program, evaluate the packed match bitmap over the arena
        epoch, then exact-verify the candidate slots against the
        primary arenas — the discovery index's superset discipline, so
        the result is byte-identical to the scan path in scan order.
        None -> inexpressible program or kernels disabled (caller
        scans)."""
        if not self._kernels_enabled():
            return None
        pk_ops, pk_ref = _predeval()
        prog = pk_ref.compile_program(preds)
        if prog is None:
            plan = self.last_plan or {}
            self.last_plan = dict(plan, reason=(
                f"{plan.get('reason', '')}; program inexpressible"))
            return None
        progs = pk_ref.stack_programs([prog])
        why = (self.last_plan or {}).get("reason", "")
        parts, total = [], 0
        for si, sh in enumerate(self._index_shards()):
            n = _shard_rows(sh)
            arena = self._shard_arena(si, sh, n)
            words = pk_ops.predeval_words(arena, progs)
            cand = pk_ops.bitmap_slots(words, 0, n)
            total += len(cand)
            parts.append(disc.verify_select(sh.alive, sh.columns,
                                            sh.paths, cand, preds))
        self.last_plan = {"query": qname, "route": "kernel",
                          "reason": f"fused kernel ({why})",
                          "candidates": total}
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _scan_select(self, preds: Sequence[Tuple[str, str, object]]
                     ) -> np.ndarray:
        """The ground-truth scan: exact predicates over the ``live()``
        view (what every accelerated route must byte-match)."""
        live = self.primary.live()
        m = np.ones(len(live["path"]), dtype=bool)
        for col, op, arg in preds:
            m &= disc.eval_pred(live[col], op, arg)
        return live["path"][m]

    def _pred_query(self, qname: str,
                    preds: Sequence[Tuple[str, str, object]]
                    ) -> np.ndarray:
        """Full route cascade for an already-built predicate list (the
        Table-I methods and the serving tier's time-pinned execution
        both land here)."""
        t0 = self.telemetry.clock()
        got = self._plan_select(qname, preds)
        if got is None:
            got = self._scan_select(preds)
        plan = self.last_plan or {}
        route = (plan.get("route", "scan")
                 if plan.get("query") == qname else "scan")
        self._h_route_s.labels(route).observe(self.telemetry.clock() - t0)
        return got

    def select_many(self, specs: Sequence, now: Optional[float] = None
                    ) -> List:
        """Batched query execution (tentpole part c): every expressible
        predicate query in ``specs`` — each a ``(name, args, kw)``
        tuple — compiles into one stacked program batch, evaluated in
        ONE fused kernel pass per shard (one arena read amortized
        across the whole batch, K bitmaps out), then exact-verified per
        query. Non-predicate or inexpressible entries dispatch through
        their normal route. Results align with ``specs`` and are
        byte-identical to running each query alone; time-relative
        cutoffs all resolve against the single ``now`` (default: this
        engine's clock, read once), so a dashboard's queries agree on
        what time it is."""
        now = self.now if now is None else float(now)
        specs = [(name, tuple(args), dict(kw)) for name, args, kw in specs]
        for name, _, _ in specs:
            if name not in self.QUERY_METHODS:
                raise ValueError(
                    f"unknown query {name!r}; expected one of "
                    f"{sorted(self.QUERY_METHODS)}")
        results: List = [None] * len(specs)
        preds_by_i: Dict[int, List] = {}
        batch: List[Tuple[int, List, dict]] = []
        pk = _predeval() if self._kernels_enabled() else None
        for i, (name, args, kw) in enumerate(specs):
            preds = pred_spec(name, args, kw, now)
            if preds is None:
                continue
            preds_by_i[i] = preds
            if pk is not None:
                prog = pk[1].compile_program(preds)
                if prog is not None:
                    batch.append((i, preds, prog))
        batched = {i for i, _, _ in batch}
        if batch:
            pk_ops, pk_ref = pk
            progs = pk_ref.stack_programs([p for _, _, p in batch])
            parts: Dict[int, List] = {i: [] for i in batched}
            total = 0
            for si, sh in enumerate(self._index_shards()):
                n = _shard_rows(sh)
                arena = self._shard_arena(si, sh, n)
                words = pk_ops.predeval_words(arena, progs)
                for j, (i, preds, _) in enumerate(batch):
                    cand = pk_ops.bitmap_slots(words, j, n)
                    total += len(cand)
                    parts[i].append(disc.verify_select(
                        sh.alive, sh.columns, sh.paths, cand, preds))
            for i in batched:
                p = parts[i]
                results[i] = p[0] if len(p) == 1 else np.concatenate(p)
            self.last_plan = {"query": "select_many", "route": "kernel",
                              "batched": len(batch),
                              "fallback": len(specs) - len(batch),
                              "candidates": total}
        for i, (name, args, kw) in enumerate(specs):
            if i in batched:
                continue
            if i in preds_by_i:
                # predicate query the kernel could not take (or kernels
                # disabled): same cascade, same pinned now
                results[i] = self._pred_query(name, preds_by_i[i])
            else:
                results[i] = getattr(self, name)(*args, **kw)
        return results

    def _plan_names(self, qname: str, literals: Sequence[str],
                    match) -> Optional[np.ndarray]:
        """Accelerated name query: trigram candidates from the
        literals guaranteed in any match, verified by ``match`` (the
        exact compiled matcher). None -> caller scans (no usable
        literal, or discovery unavailable/stale)."""
        codes = disc.literal_trigrams(literals)
        if not codes:
            self.last_plan = {"query": qname, "route": "scan",
                              "reason": "no literal >= 3 bytes in pattern"}
            return None
        return self._plan(qname, lambda d: d.name_select(codes, match))

    # -- individual-granularity queries (primary index) ----------------------

    def stat(self, path: str) -> Optional[Dict]:
        """Point lookup by exact subject: one slot-map probe — on a
        sharded primary this routes to the single owning shard, no
        scatter (DESIGN.md §8)."""
        return self.primary.lookup(path)

    def find_by_name(self, pattern: str) -> np.ndarray:
        """name LIKE "*pattern*" (regex-match raw mode). Planner: the
        literals guaranteed in any match prefilter through the trigram
        index; each candidate is verified with the real compiled regex,
        so results are byte-identical to the scan. Fallback (stale
        index / no >=3-byte literal): scan the path-only live view
        (``live_paths``) — no full-column materialization — with the
        regex compiled once and its bound ``search`` applied in a
        single comprehension pass."""
        search = re.compile(pattern).search
        got = self._plan_names("find_by_name", disc.regex_literals(pattern),
                               lambda p: search(p) is not None)
        if got is not None:
            return got
        paths = self.primary.live_paths()
        return paths[[i for i, p in enumerate(paths) if search(p)]]

    def find_by_glob(self, pattern: str) -> np.ndarray:
        """name LIKE a shell glob (the web interface's non-regex search
        box). Same planner/fallback split as ``find_by_name``, with
        ``fnmatch.fnmatchcase`` as the exact verifier."""
        got = self._plan_names(
            "find_by_glob", disc.glob_literals(pattern),
            lambda p: fnmatch.fnmatchcase(p, pattern))
        if got is not None:
            return got
        paths = self.primary.live_paths()
        return paths[[i for i, p in enumerate(paths)
                      if fnmatch.fnmatchcase(p, pattern)]]

    def world_writable(self) -> np.ndarray:
        """Table I "world-writable files" (security audit): mode & 0o002.
        Route cascade (``_pred_query``): discovery-index mode-run sweep
        -> fused predicate kernel -> live() scan, all byte-identical."""
        return self._pred_query("world_writable",
                                [("mode", "mask", 0o002)])

    def not_accessed_since(self, seconds: float) -> np.ndarray:
        """Table I "not accessed in N months" (cold-data candidates)."""
        return self._pred_query("not_accessed_since",
                                [("atime", "lt", self.now - seconds)])

    def large_cold_files(self, min_size: float, idle_seconds: float) -> np.ndarray:
        """Table I "large files with low access" (tiering candidates).

        ``min_size`` compares against the float32 ``size`` arena — see
        the storage-dtype rounding contract (DESIGN.md §13.5): above
        2^24 bytes the STORED size is the float32 rounding of the true
        size, and the threshold itself is rounded to float32 before the
        compare (numpy weak-scalar promotion). Every route — scan,
        discovery, kernel — applies the same rounding; the directed
        boundary test in tests/test_query_fixes.py pins agreement."""
        return self._pred_query("large_cold_files",
                                [("size", "gt", min_size),
                                 ("atime", "lt", self.now - idle_seconds)])

    def duplicate_candidates(self) -> Dict[int, np.ndarray]:
        """GROUP BY checksum HAVING count > 1 (``path_hash`` as the
        stand-in checksum column), keyed by the hash value. Same-size
        files with different hashes are NOT candidates — grouping by
        ``size`` here was a bug that flooded the report on any corpus
        with repeated sizes.

        Grouping is one stable argsort + boundary scan: the previous
        implementation rescanned the full inverse array once per
        duplicated group (``inv == ui`` in a Python loop — O(groups *
        n), quadratic on dedup-heavy corpora; the regression test in
        tests/test_query_fixes.py bounds the fixed cost). Stable sort
        keeps live-row order within each group, so the output is
        identical: keys ascending, paths in scan order."""
        live = self.primary.live()
        hashes = live["path_hash"].astype(np.int64)
        order = np.argsort(hashes, kind="stable")
        h = hashes[order]
        starts = np.flatnonzero(np.r_[True, h[1:] != h[:-1]])
        ends = np.r_[starts[1:], len(h)]
        paths = live["path"]
        out = {}
        for gi in np.flatnonzero(ends - starts > 1):
            s = starts[gi]
            out[int(h[s])] = paths[order[s:ends[gi]]]
        return out

    def owned_by_deleted_users(self, active_uids: Sequence[int]) -> np.ndarray:
        """Table I "files owned by deleted users" (orphan sweep)."""
        return self._pred_query("owned_by_deleted_users",
                                [("uid", "notin", list(active_uids))])

    def past_retention(self, retention_seconds: float) -> np.ndarray:
        """Table I "past retention policy" (purge candidates)."""
        return self._pred_query(
            "past_retention",
            [("mtime", "lt", self.now - retention_seconds)])

    # -- aggregate-granularity queries (aggregate index) ----------------------

    def directories_over(self, n_files: float) -> List[str]:
        """Table I "directories with > N entries". Aggregate-index read:
        per-principal records are whole (never half-written), but may
        trail the primary index by one apply (DESIGN.md §6.3)."""
        return [p for p, c in self.aggregate.records.items()
                if p.startswith("dir:") and c["file_count"] > n_files]

    def storage_by_project(self) -> Dict[str, float]:
        """SUM(size) GROUP BY project — projects are groups here."""
        return {p: c["size"]["total"] for p, c in self.aggregate.records.items()
                if p.startswith("group:")}

    def quota_pressure(self, quotas: Dict[str, float], thresh: float = 0.9
                       ) -> List[Tuple[str, float]]:
        """Table I "principals near quota": total size / quota > thresh."""
        out = []
        for p, c in self.aggregate.records.items():
            q = quotas.get(p)
            if q and c["size"]["total"] / q > thresh:
                out.append((p, c["size"]["total"] / q))
        return out

    def most_small_files(self, k: int = 10) -> List[Tuple[str, float]]:
        """COUNT(file_size < 1MB) DESC per user — estimated from each
        user's size-sketch CDF at 1 MB (sketch-powered semantic query)."""
        live = self.primary.live()
        # exact path for validation:
        users, counts = np.unique(live["uid"][live["size"] < 1e6],
                                  return_counts=True)
        order = np.argsort(-counts)
        return [(f"user:{int(users[i])}", float(counts[i]))
                for i in order[:k]]

    def per_user_usage(self) -> Dict[str, Tuple[float, float]]:
        """SUM(size), COUNT(*) GROUP BY uid."""
        return {p: (c["size"]["total"], c["file_count"])
                for p, c in self.aggregate.records.items()
                if p.startswith("user:")}

    def dir_size_percentile(self, q: str = "p99") -> Dict[str, float]:
        """PERCENTILE(size, q) for directory principals."""
        return {p: c["size"][q] for p, c in self.aggregate.records.items()
                if p.startswith("dir:")}

    def top_storage_users(self, k: int = 10) -> List[Tuple[str, float]]:
        """Table I "top storage consumers" (admin dashboard staple)."""
        items = [(p, c["size"]["total"])
                 for p, c in self.aggregate.records.items()
                 if p.startswith("user:")]
        items.sort(key=lambda x: -x[1])
        return items[:k]

    # -- subtree-rollup queries (DESIGN.md §14) -------------------------------
    #
    # du-on-any-directory and friends route through the attached
    # HierarchyIndex when its rollups are exact (bounded lazy
    # propagation, O(dirty + answer)); otherwise they fall back to a
    # brute-force scan over ``live()``. Both routes share the
    # quantization contract (hierarchy.size_bytes_i64 / atime_bucket),
    # so results are byte-identical — tests/test_rollup.py pins it.

    def _hier_route(self, name: str):
        """(hierarchy | None, plan) — hierarchy is None on fallback."""
        h = self.hierarchy
        if h is None:
            return None, {"query": name, "route": "scan",
                          "reason": "no hierarchy index attached"}
        if not h.exact:
            return None, {"query": name, "route": "scan",
                          "reason": "rollups invalidated (bulk load or "
                                    "compaction without reseed)"}
        return h, {"query": name, "route": "rollup", "reason": "exact"}

    def du(self, path: str, depth: int = 0) -> Dict:
        """The paper's flagship admin query at last: aggregate summary
        statistics for ANY directory — live file count, total bytes
        (int64-quantized), max mtime — plus per-subdirectory rows down
        to ``depth`` levels below ``path`` (0 = totals only)."""
        h, plan = self._hier_route("du")
        self.last_plan = plan
        if h is not None:
            return h.du(path, depth=depth)
        return hier.du_scan(self.primary.live(), path, depth=depth)

    def subtree_summary(self, path: str) -> Dict:
        """``du`` totals plus the coarse atime histogram (bucket counts
        and bytes over hierarchy.ATIME_EDGES_S, anchored at REF_TIME)
        and the number of distinct directories holding live files —
        the retention/tiering view a policy rule evaluates against."""
        h, plan = self._hier_route("subtree_summary")
        self.last_plan = plan
        if h is not None:
            return h.subtree_summary(path)
        return hier.subtree_summary_scan(self.primary.live(), path)

    def hot_directories(self, k: int = 10, buckets: int = 2) -> List[Dict]:
        """Top-k directories by own-grain (non-recursive) bytes in the
        ``buckets`` most-recent atime buckets — "where is the hot data"
        at directory granularity, REF_TIME-anchored so the ranking is
        a property of the corpus, not of when you asked."""
        h, plan = self._hier_route("hot_directories")
        self.last_plan = plan
        if h is not None:
            return h.hot_directories(k=k, buckets=buckets)
        return hier.hot_directories_scan(self.primary.live(),
                                         k=k, buckets=buckets)

    # -- the full Table I suite, timed (for bench_index_query) ----------------

    def run_table1_suite(self) -> Dict[str, float]:
        timings = {}

        def timed(name, fn, *a):
            t0 = time.perf_counter()
            fn(*a)
            timings[name] = time.perf_counter() - t0

        timed("name_like", self.find_by_name, r"f1\d\d$")
        timed("world_writable", self.world_writable)
        timed("not_accessed_12m", self.not_accessed_since, 365 * 86400)
        timed("large_low_access", self.large_cold_files, 100e9, 180 * 86400)
        timed("duplicates", self.duplicate_candidates)
        timed("dirs_over_100k", self.directories_over, 100_000)
        timed("storage_by_project", self.storage_by_project)
        timed("quota_pressure", self.quota_pressure,
              {p: 1e12 for p in self.aggregate.records}, 0.9)
        timed("deleted_users", self.owned_by_deleted_users, list(range(16)))
        timed("past_retention", self.past_retention, 2 * 365 * 86400)
        timed("most_small_files", self.most_small_files)
        timed("per_user_usage", self.per_user_usage)
        timed("dir_p99", self.dir_size_percentile)
        return timings
