"""Event-based ingestion into the dual index (paper §IV-B; DESIGN.md §6).

The missing half of the ingestion story: snapshot.py bulk-loads a scan,
this module keeps both indexes synchronized from a *changelog event
stream* (Lustre MDT changelog / GPFS watch analogue, events.py), so the
indexed view tracks the file system in real time instead of decaying
until the next scan.

Pipeline per applied batch, mirroring the paper's Flink ingest job:

1. **Coalesce** (paper §IV-B2 rule 1+2, host/numpy): sort by (fid, seq),
   keep the last event per fid as its representative, annihilate
   created-then-deleted fids. All segment facts (last parent, last stat,
   last name) are computed with vectorized last-write-wins scatters — no
   per-event Python loop.
2. **State manager** (paper §IV-B3): fold surviving facts into host
   fid->(parent, name, stat) tables; directory renames re-path every
   live descendant (tombstone at the old subject, upsert at the new one)
   — the paper's rename override.
3. **Primary index**: one vectorized ``upsert_batch`` + ``delete_batch``
   per applied batch (batched slot assignment; columnar scatters).
4. **Aggregate index**: grouped per-principal updates on device — object
   counts through the ``segstats`` kernel, attribute sketches through the
   grouped-DDSketch kernel (``use_kernel=True``) or their jnp references
   — then republish only the touched principals.

Consistency modes (paper's tunable consistency/latency/freshness knobs):

- ``eager``: every ``ingest()`` call applies immediately. Maximum
  freshness, one device dispatch per call.
- ``buffered``: events accumulate and apply when ``max_buffer_events``
  or the ``freshness_window`` wall-clock deadline is hit (size/time
  batching exactly like the paper's 10 MB / 5 s ingest batcher).
  Maximum throughput; queries may trail the stream by up to the window.

Snapshot -> event handoff: events address objects by fid, the snapshot
index by path. Bootstrap the ingestor with ``register_tree`` (the
scanner's fid -> (parent, name) map) so changelog events on pre-scan
files resolve to the subjects the snapshot loaded; events on unknown
fids fall back to ``#fid`` subjects and are counted in
``metrics["unresolved"]``.

Either way every reader can ask for the **watermark**: the highest
changelog seq folded into the indexes, the number of buffered-but-unapplied
events, and the staleness clock. QueryEngine surfaces it next to query
results (DESIGN.md §6.3).

Discovery-index maintenance (DESIGN.md §11): every apply's primary
mutations — version-gated upserts, tombstones, rename repaths, repair
batches — publish their touched slots into any attached
``discovery.ShardDiscovery`` delta buffers through the primary's
mutation hooks, so replay/repair/rename flows keep the secondary
indexes exact without this module special-casing them; ``freshness()``
exports the resulting ``index_lag`` mark.

What a reader observes mid-ingest: the primary index is updated between
``ingest()`` calls only; within one applied batch, upserts land before
tombstones, and aggregate summaries republish after the primary columns —
so a reader interleaved with an apply can see a subject whose aggregate
summary is one batch older (per-key eventual consistency). Sketch
observations are recorded once per newly-seen subject; attribute updates
and deletes reach the aggregate quantiles at the next snapshot rebuild
(bounded-staleness trade-off, DESIGN.md §6.2).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import events as ev
from repro.core import metadata as md
from repro.core import snapshot as snap
from repro.core.discovery import index_lag as discovery_index_lag
from repro.core.hierarchy import HierarchyIndex, resolve_paths_host
from repro.core.index import (AggregateIndex, PrimaryIndex, bucket_pow2,
                              pack_array, pad_1d, unpack_array)
from repro.core.sketches import ddsketch as dds
from repro.core.telemetry import resolve as _resolve_tel

MODES = ("eager", "buffered")


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Knobs for the consistency/latency/freshness trade (paper §V-C)."""

    mode: str = "eager"              # "eager" | "buffered"
    freshness_window: float = 5.0    # buffered: max seconds before an apply
    max_buffer_events: int = 8192    # buffered: size trigger
    pad_to: int = 1024               # pad device batches (stable jit shapes)
    use_kernel: bool = False         # Pallas segstats/ddsketch kernels
    filter_opens: bool = True        # drop OPEN events before coalescing
    update_aggregates: bool = True   # maintain the aggregate index too
    track_hierarchy: bool = True     # maintain subtree rollups (§14)

    def __post_init__(self):
        assert self.mode in MODES, self.mode


@dataclasses.dataclass
class Watermark:
    """Freshness metadata readers attach to query results (DESIGN.md §6.3).

    ``applied_seq`` is the highest changelog sequence number whose effect
    is visible in both indexes; everything at or below it is readable.
    ``pending`` counts buffered events not yet applied (always 0 in eager
    mode). ``last_apply_time`` is on the ingestor's clock (monotonic by
    default) so staleness = clock() - last_apply_time.

    ``reconciled_at`` is when the last anti-entropy reconcile completed
    (core/reconcile.py; 0.0 = never): the moment the index was last
    known to agree with a full snapshot, i.e. the bound on how long
    dropped-event drift can have been accumulating. Like
    ``last_apply_time`` it is ON THE INGESTOR'S CLOCK (monotonic by
    default, NOT wall-clock epoch) — compute ages as clock() minus the
    mark, never compare it against ``time.time``; pass
    ``clock=time.time`` at construction if epoch marks are wanted.
    """

    applied_seq: int = 0
    pending: int = 0
    last_apply_time: float = 0.0
    applied_batches: int = 0
    reconciled_at: float = 0.0


# ---------------------------------------------------------------------------
# device steps (jitted once per (config, padded-shape))
# ---------------------------------------------------------------------------

def _fold_sketch(scfg, state, vals, pids, mask, update_grouped):
    """state (P, A, NB); vals (A, N); pids/mask (N,): per-attribute
    grouped update, generic over the update implementation."""
    n_principals = state["count"].shape[0]
    for ai in range(vals.shape[0]):
        sub = jax.tree.map(lambda s: s[:, ai], state)
        sub = update_grouped(scfg, sub, vals[ai], pids, n_principals,
                             mask=mask)
        state = jax.tree.map(lambda s, ns: s.at[:, ai].set(ns), state, sub)
    return state


@functools.partial(jax.jit, static_argnums=(0,))
def _sketch_apply_ref(scfg: dds.DDSketchConfig, state, vals, pids, mask):
    return _fold_sketch(scfg, state, vals, pids, mask, dds.update_grouped)


def _sketch_apply_kernel(scfg, state, vals, pids, mask):
    from repro.kernels.ddsketch import ops as dd_ops
    return _fold_sketch(scfg, state, vals, pids, mask,
                        dd_ops.update_grouped)


@functools.partial(jax.jit, static_argnums=(3, 4))
def _count_apply_ref(pids, sids, weights, n_principals, n_shards):
    counts = jnp.zeros((n_principals, n_shards), jnp.float32)
    return counts.at[pids, sids].add(weights)


# shared with AggregateIndex publication: one bucketing rule, one shape
# universe (index.bucket_pow2 / index.pad_1d)
_bucket = bucket_pow2
_pad = pad_1d


class EventIngestor:
    """Consumes changelog event batches, keeps PrimaryIndex + AggregateIndex
    synchronized, and exports a freshness watermark (paper §IV-B).

    Versioning: primary-index versions ARE changelog sequence numbers —
    snapshots and events share one logical clock (give ``ingest_table`` the
    changelog seq at scan time as its version), which is what makes replay
    of any event suffix idempotent (paper §IV-A1).
    """

    def __init__(self, cfg: IngestConfig, pcfg: snap.PipelineConfig,
                 primary: PrimaryIndex, aggregate: AggregateIndex,
                 names: Optional[Dict[int, str]] = None,
                 principal_names: Optional[Sequence[str]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 telemetry=None):
        """``primary`` may be a monolithic ``PrimaryIndex`` or a
        ``sharded_index.ShardedPrimaryIndex`` — the ingestor only uses
        the shared mutation protocol (upsert_batch / delete_batch /
        get_record). With a sharded primary, each coalesced micro-batch
        routes per shard by path hash inside the index; THIS ingestor
        still owns the single global watermark/version clock, so
        freshness semantics are identical. A rename that migrates a
        record between shards is already a delete+upsert pair here (old
        subject tombstone + new subject upsert) and each half routes
        independently (DESIGN.md §8)."""
        self.cfg = cfg
        self.pcfg = pcfg
        self.primary = primary
        self.aggregate = aggregate
        self.clock = clock
        self.watermark = Watermark(last_apply_time=clock())
        #: optional () -> int: events durably produced but not yet
        #: committed behind this ingestor (the durable pipeline's
        #: consumer lag, core/stream_pipeline.py) — surfaced in
        #: freshness() as ``log_lag`` next to the watermark
        self.lag_source: Optional[Callable[[], int]] = None
        #: watermark-advance listeners, called as cb(applied_seq,
        #: mutated) at the END of each apply, still under the primary's
        #: write lock. ``mutated`` is False for no-op applies (e.g. an
        #: all-OPEN batch coalescing to nothing): the watermark moved
        #: but the readable state did not — the serving tier's result
        #: cache keys off exactly this distinction (query_service.py)
        self.on_apply: List[Callable[[int, bool], None]] = []
        self.metrics = {"events_in": 0, "applied": 0, "upserts": 0,
                        "tombstones": 0, "cancelled": 0, "repathed": 0,
                        "applies": 0, "sketch_rows": 0, "unresolved": 0,
                        "reconciles": 0, "repair_upserts": 0,
                        "repair_tombstones": 0}
        # registry instruments next to (never replacing) self.metrics:
        # the dict is serialized by state_dict() and byte-compared by the
        # crash/differential suites, so it stays the durable source of
        # truth while telemetry is the scrape surface
        self.telemetry = _resolve_tel(telemetry)
        self._c_events_in = self.telemetry.counter(
            "ingest_events_total", "changelog events handed to ingestors")
        self._h_apply_s = self.telemetry.histogram(
            "ingest_apply_seconds",
            "one coalesced apply under the write lock")
        self._g_applied_seq = self.telemetry.gauge(
            "ingest_watermark_applied_seq",
            "highest changelog seq visible to readers")
        self._g_pending = self.telemetry.gauge(
            "ingest_pending_events", "buffered events not yet visible")
        # host state-manager tables (fid-keyed)
        self._name: Dict[int, str] = dict(names or {})
        self._parent: Dict[int, int] = {}
        self._children: Dict[int, set] = {}
        self._stat: Dict[int, Dict] = {}
        self._is_dir: Dict[int, bool] = {}
        # device aggregate operator state
        self._sketch_state = dds.init(
            pcfg.sketch, (pcfg.n_principals, len(snap.ATTRS)))
        self.counts = np.zeros((pcfg.n_principals, pcfg.n_shards), np.float32)
        # counts start exact (empty index as far as this ingestor knows)
        # and stay exact under event deltas; a snapshot handoff
        # (register_tree) loads records behind the delta stream's back,
        # so exactness then requires seed_counts() with the snapshot
        # counting pipeline's matrix
        self._counts_seeded = False
        self._tree_registered = False
        self._principal_names = (list(principal_names) if principal_names
                                 else [f"user:{i}" for i in range(pcfg.n_users)]
                                 + [f"group:{i}" for i in range(pcfg.n_groups)]
                                 + [f"dir:{i}" for i in range(pcfg.n_dirs)])
        # subtree-rollup tree (DESIGN.md §14): mirrors the primary's
        # live non-dir subjects by post-mutation probe read-back; owned
        # by this ingestor so every apply/repair/restore keeps it in
        # lockstep with the watermark
        self.hierarchy: Optional[HierarchyIndex] = None
        if cfg.track_hierarchy and hasattr(primary, "probe"):
            self.hierarchy = HierarchyIndex()
            attach = getattr(primary, "attach_rollups", None)
            if attach is not None:
                attach(self.hierarchy)
        # buffered mode
        self._buffer: List[Dict[str, np.ndarray]] = []
        self._buffered = 0
        self._first_buffer_ts: Optional[float] = None

    # -- public surface -------------------------------------------------------

    def ingest(self, batch: Dict[str, np.ndarray],
               names: Optional[Dict[int, str]] = None) -> Dict[str, int]:
        """Feed one changelog micro-batch (events.empty_batch layout).

        ``eager``: applied before this call returns — a subsequent query
        reads every effect. ``buffered``: visible only after the size or
        freshness trigger fires (or an explicit flush()). ``names`` merges
        fid -> path-component bindings (EventStream.names side table).
        """
        if names:
            self._name.update(names)
        n = len(batch["fid"])
        self.metrics["events_in"] += n
        self._c_events_in.inc(n)
        if n == 0:
            return {"applied": 0, "pending": self.watermark.pending}
        if self.cfg.mode == "eager":
            applied = self._apply([batch])
        else:
            self._buffer.append({k: np.asarray(v).copy()
                                 for k, v in batch.items()})
            self._buffered += n
            if self._first_buffer_ts is None:
                self._first_buffer_ts = self.clock()
            self.watermark.pending = self._buffered
            applied = 0
            if (self._buffered >= self.cfg.max_buffer_events
                    or self.clock() - self._first_buffer_ts
                    >= self.cfg.freshness_window):
                applied = self.flush()
        return {"applied": applied, "pending": self.watermark.pending}

    def tick(self) -> int:
        """Time-based flush check for buffered mode (call from the driver
        loop, like IngestBatcher.tick)."""
        if (self._buffer and self._first_buffer_ts is not None
                and self.clock() - self._first_buffer_ts
                >= self.cfg.freshness_window):
            return self.flush()
        return 0

    def flush(self) -> int:
        """Apply everything buffered, advancing the watermark."""
        if not self._buffer:
            return 0
        batches, self._buffer = self._buffer, []
        self._buffered = 0
        self._first_buffer_ts = None
        return self._apply(batches)

    def apply_repairs(self, up_paths: Sequence[str],
                      up_fields: Dict[str, np.ndarray],
                      del_paths: Sequence[str], del_uid: np.ndarray,
                      del_gid: np.ndarray, version: int,
                      del_hashes: Optional[np.ndarray] = None
                      ) -> Dict[str, int]:
        """Apply synthetic create/update/delete repair batches from the
        anti-entropy reconciler (core/reconcile.py; DESIGN.md §9.1)
        through the SAME primary-mutation + aggregate-delta path an
        event batch takes, under the shared logical clock: every repair
        carries ``version`` — the changelog seq at the snapshot's scan
        time — so the ``>=`` version gate drops any repair that races a
        fresher event effect (a record the live feed updated after the
        scan keeps its newer value; one it deleted after the scan stays
        dead). Buffered events are flushed first so repairs land on the
        applied state the reconciler diffed. Advances the watermark to
        ``version`` and stamps ``reconciled_at``.

        ``del_uid`` / ``del_gid`` are the owners of the to-be-deleted
        records (read from the index by the reconciler) — the counting
        pipeline's -1 deltas must land on the real principals — and
        ``del_hashes`` their stored FNV hashes, so routing the
        tombstones costs no re-hash.
        """
        self.flush()
        with self._write_lock():
            n_up = len(up_paths)
            up_paths = list(up_paths)
            del_paths = list(del_paths)
            new_mask = self.primary.upsert_batch(
                up_paths, up_fields, np.full(n_up, version, np.int64))
            del_mask = self.primary.delete_batch(
                del_paths, np.full(len(del_paths), version, np.int64),
                hashes=del_hashes)
            up_uid = np.asarray(up_fields["uid"]) if n_up else \
                np.zeros(0, np.int32)
            up_gid = np.asarray(up_fields["gid"]) if n_up else \
                np.zeros(0, np.int32)
            if self.cfg.update_aggregates:
                count_jobs = [(up_paths, up_uid, up_gid, +1.0, new_mask),
                              (del_paths, np.asarray(del_uid, np.int32),
                               np.asarray(del_gid, np.int32), -1.0,
                               del_mask)]
                up_size = (np.asarray(up_fields["size"], np.float32)
                           if n_up else np.zeros(0, np.float32))
                up_mtime = (np.asarray(up_fields["mtime"], np.float32)
                            if n_up else np.zeros(0, np.float32))
                self._apply_aggregates(count_jobs, up_paths, up_uid,
                                       up_gid, up_size, up_mtime,
                                       new_mask)
            if self.hierarchy is not None:
                # repairs are file-grain: mirror-sync both sides through
                # the same probe read-back the event path uses
                self.hierarchy.apply_ops(
                    [("sync", p)
                     for p in dict.fromkeys([*del_paths, *up_paths])],
                    self._probe)
            self.metrics["reconciles"] += 1
            self.metrics["repair_upserts"] += n_up
            self.metrics["repair_tombstones"] += int(del_mask.sum())
            self._advance_watermark(version)
            self.watermark.reconciled_at = self.clock()
            self._notify_applied(int(version), mutated=True)
            return {"upserts": n_up, "tombstones": int(del_mask.sum()),
                    "entered": int(new_mask.sum())}

    def principals_of(self, paths: Sequence[str], uid: np.ndarray,
                      gid: np.ndarray) -> set:
        """Principal slot ids the given records contribute to (uid slot,
        gid slot, dir-prefix slots) — what the reconcile/compaction path
        uses to scope republication."""
        out: set = set()
        if len(paths):
            for pid, w in self._principal_rows(
                    list(paths), np.asarray(uid, np.int32),
                    np.asarray(gid, np.int32))[0]:
                out.update(np.unique(pid[w != 0]).tolist())
        return out

    @property
    def counts_exact(self) -> bool:
        """Whether ``counts`` speaks for the whole index: True unless a
        snapshot handoff (``register_tree``) loaded records this
        ingestor's delta stream never saw and ``seed_counts`` was not
        called. Republication passes exact counts — and therefore drops
        zero-count principals — only when this holds; otherwise a zero
        only means "nothing observed HERE" and must not delete
        snapshot-built summaries."""
        return self._counts_seeded or not self._tree_registered

    def seed_counts(self, counts: np.ndarray) -> None:
        """Seed the (P, S) counting matrix from the snapshot counting
        pipeline's output — the aggregate half of the snapshot -> event
        handoff (``register_tree`` is the primary-index half). After
        seeding, event deltas keep the matrix exact over BOTH
        snapshot-loaded and event-born records, re-arming the
        zero-count ghost-principal drop."""
        counts = np.asarray(counts, np.float32)
        assert counts.shape == self.counts.shape, \
            (counts.shape, self.counts.shape)
        self.counts = counts.copy()
        self._counts_seeded = True

    def _exact_counts(self) -> Optional[np.ndarray]:
        return self.counts.sum(axis=1) if self.counts_exact else None

    def republish(self, principal_ids: Sequence[int]) -> None:
        """Republish the given principals from current sketch state with
        EXACT counts when available (``counts_exact``): principals whose
        live count has dropped to zero are removed from the aggregate
        index instead of lingering as ghosts — the reconcile/compaction
        path's way of flushing dead principals
        (``AggregateIndex.from_sketch_state(only=...)``). No-op when
        aggregate maintenance is disabled."""
        ids = sorted({int(p) for p in principal_ids})
        if not ids or not self.cfg.update_aggregates:
            return
        self.aggregate.from_sketch_state(
            self.pcfg.sketch, self._sketch_state, self._principal_names,
            only=ids, counts=self._exact_counts())

    def freshness(self) -> Dict[str, float]:
        """The watermark readers attach to results (DESIGN.md §6.3).

        ``log_lag`` counts log RECORDS (payloads — micro-batch slices,
        Kafka-style consumer lag, NOT single events like
        ``pending_events``) durably in the log but not yet committed
        behind this ingestor (0 for direct-fed deployments): with
        commit-after-apply it bounds how much replay a crash-restart
        would re-run, and for readers it is the freshness gap BEYOND
        ``pending_events`` — records the broker holds that this index
        has not even buffered yet (DESIGN.md §10.4).

        ``index_lag`` is the discovery-index freshness mark (DESIGN.md
        §11.3): primary mutations not reflected in queryable secondary-
        index state, summed over shards. 0 means the query planner's
        accelerated answers are exact (every apply this ingestor runs
        publishes its touched slots into the discovery delta buffers
        through the primary's version-gated mutation hooks, so the mark
        stays 0 under pure event flow); nonzero means discovery was
        invalidated (bulk snapshot ingest, state restore) and selective
        queries are scanning until a rebuild. Also 0 when no discovery
        index is attached."""
        return {
            "mode": self.cfg.mode,
            "applied_seq": self.watermark.applied_seq,
            "pending_events": self.watermark.pending,
            "staleness_s": max(0.0, self.clock()
                               - self.watermark.last_apply_time),
            "applied_batches": self.watermark.applied_batches,
            "reconciled_at": self.watermark.reconciled_at,
            "log_lag": int(self.lag_source()) if self.lag_source else 0,
            "index_lag": discovery_index_lag(self.primary),
            "rollup_dirty": (self.hierarchy.dirty_count()
                             if self.hierarchy is not None else 0),
            "rollup_exact": (bool(self.hierarchy.exact)
                             if self.hierarchy is not None else False),
        }

    # -- checkpoint / restore (DESIGN.md §10.3) -------------------------------

    def state_dict(self) -> Dict:
        """Serializable ingestor state: the fid-keyed state-manager
        tables, the device sketch state, the exact counting matrix, and
        the watermark. Together with the primary index's ``state_dict``
        this is everything crash recovery needs to resume the stream —
        restore + replay of the post-barrier suffix reproduces the
        uninterrupted run byte-for-byte. Buffered events are NOT
        serialized: callers flush first (the durable pipeline's
        checkpoint barrier is an applied-state barrier)."""
        assert not self._buffer, "flush() before state_dict()"
        return {
            "watermark": {
                "applied_seq": int(self.watermark.applied_seq),
                "applied_batches": int(self.watermark.applied_batches),
                "reconciled_at": float(self.watermark.reconciled_at),
            },
            "metrics": {k: int(v) for k, v in self.metrics.items()},
            "name": {int(k): v for k, v in self._name.items()},
            "parent": {int(k): int(v) for k, v in self._parent.items()},
            "children": {int(k): sorted(int(c) for c in v)
                         for k, v in self._children.items()},
            "stat": {int(k): {kk: (float(vv) if kk not in ("uid", "gid")
                                   else int(vv)) for kk, vv in st.items()}
                     for k, st in self._stat.items()},
            "is_dir": sorted(int(k) for k, v in self._is_dir.items() if v),
            "sketch": {k: pack_array(v)
                       for k, v in self._sketch_state.items()},
            "counts": pack_array(self.counts),
            "counts_seeded": self._counts_seeded,
            "tree_registered": self._tree_registered,
            "hierarchy": (self.hierarchy.state_dict()
                          if self.hierarchy is not None else None),
        }

    def load_state(self, state: Dict) -> None:
        """Restore ``state_dict`` output in place. The ingestor must be
        constructed with the same (cfg, pcfg) shape universe; the
        primary/aggregate indexes are restored separately (they carry
        their own state). Held under the primary write lock so a
        concurrent snapshot never pins a half-restored ingestor."""
        with self._write_lock():
            self._load_state_inner(state)

    def _load_state_inner(self, state: Dict) -> None:
        wm = state["watermark"]
        self.watermark = Watermark(
            applied_seq=int(wm["applied_seq"]),
            applied_batches=int(wm["applied_batches"]),
            reconciled_at=float(wm["reconciled_at"]),
            last_apply_time=self.clock())
        self.metrics.update(state["metrics"])
        self._name = {int(k): v for k, v in state["name"].items()}
        self._parent = {int(k): int(v) for k, v in state["parent"].items()}
        self._children = {int(k): set(v)
                          for k, v in state["children"].items()}
        self._stat = {int(k): dict(st) for k, st in state["stat"].items()}
        self._is_dir = {int(k): True for k in state["is_dir"]}
        self._sketch_state = {k: jnp.asarray(unpack_array(v))
                              for k, v in state["sketch"].items()}
        counts = unpack_array(state["counts"])
        assert counts.shape == self.counts.shape, \
            (counts.shape, self.counts.shape)
        self.counts = counts
        self._counts_seeded = bool(state["counts_seeded"])
        self._tree_registered = bool(state["tree_registered"])
        # restore the rollup tree AFTER the primary's load_state ran
        # (its _mutated(None) invalidated the attached hierarchy; the
        # serialized state re-establishes exactness). A checkpoint that
        # predates rollups restores as invalid -> scan fallback.
        if self.hierarchy is not None:
            self.hierarchy.load_state(state.get("hierarchy"))
        self._buffer, self._buffered = [], 0
        self._first_buffer_ts = None
        # aggregate records are derived state (not serialized):
        # republish every principal from the restored sketch + counts so
        # readers see summaries immediately after a restore
        if self.cfg.update_aggregates:
            self.republish(range(self.pcfg.n_principals))
        # a restore rewinds/replaces readable state wholesale: cached
        # results keyed at any prior watermark are void
        self._notify_applied(int(self.watermark.applied_seq), mutated=True)

    # -- the apply pipeline ---------------------------------------------------

    def _write_lock(self):
        """The primary's MVCC write lock (DESIGN.md §12), or a no-op
        context on duck-typed primaries predating ``write_lock``. Held
        across one WHOLE apply, so a concurrent ``snapshot()`` pins
        batch boundaries only — never a half-applied event batch."""
        wl = getattr(self.primary, "write_lock", None)
        return wl() if wl is not None else contextlib.nullcontext()

    def _notify_applied(self, seq: int, mutated: bool) -> None:
        for cb in self.on_apply:
            cb(seq, mutated)

    # -- subtree-rollup publication (DESIGN.md §14) ---------------------------

    def _probe(self, path: str):
        return self.primary.probe(path)

    def _publish_hierarchy(self, facts, resolve, dead_fids, dead_paths,
                           mv_old, rend_fids, rend_old, up_paths,
                           re_paths) -> None:
        """Emit one applied chunk's rollup ops IN PHASE ORDER:

        1. syncs at OLD keys (deletes + file-rename sources) — before any
           subtree re-key can move the registry entries out from under
           those paths;
        2. whole-subtree moves for renamed dirs — before this batch's
           dir creates, so an ensure-chain can never plant a colliding
           synthetic node at a path a move is about to claim;
        3. dir registrations (alive dirs at their post-fold paths);
        4. rmdirs (dead dirs at their pre-fold paths — a dead dir keeps
           its path mapping for residual-file rollups);
        5. syncs at NEW keys (upserts + both sides of every repath pair
           — the old side backstops version-gate-dropped repaths).

        Every sync probes the primary's post-batch state, so the mirror
        converges on exactly what the version gates actually applied."""
        isdir_of = {int(f): bool(d)
                    for f, d in zip(facts["fid"], facts["is_dir"])}
        ops: List[tuple] = []
        for p in dict.fromkeys([*dead_paths, *mv_old]):
            ops.append(("sync", p))
        moves = [(int(f), old, resolve(int(f)))
                 for f, old in zip(rend_fids, rend_old)]
        if moves:
            # ONE batched op: same-batch move sets can permute arbitrarily
            # (swaps, nested moves), so they detach/attach as a group
            ops.append(("move_dirs", moves))
        live_dirs = facts["is_dir"] & facts["alive"]
        for f in facts["fid"][live_dirs]:
            ops.append(("dir", int(f), resolve(int(f))))
        for f, p in zip(dead_fids, dead_paths):
            if isdir_of.get(int(f)):
                ops.append(("rmdir", int(f), p))
        re_old = re_paths.get("old", []) if re_paths else []
        re_new = re_paths.get("new", []) if re_paths else []
        for p in dict.fromkeys([*up_paths, *re_old, *re_new]):
            ops.append(("sync", p))
        self.hierarchy.apply_ops(ops, self._probe)

    def _seed_hierarchy(self) -> None:
        """Rebuild the rollup tree from the registered fid tree + the
        primary's live view — the snapshot handoff's hierarchy half
        (register_tree is the resolver half, seed_counts the aggregate
        half). Restores ``exact`` after bulk ingest invalidation."""
        h = self.hierarchy
        if h is None:
            return
        dir_fids = sorted({f for f, d in self._is_dir.items() if d}
                          | {p for p in self._parent.values() if p >= 0})
        try:
            paths = resolve_paths_host(self._parent, self._name, dir_fids)
        except ValueError:               # cycle/overflow: corrupt tree
            h.invalidate()
            return
        pairs = [(f, p) for f, p in zip(dir_fids, paths) if p is not None]
        h.seed(pairs, self.primary.live())

    def _apply(self, batches: List[Dict[str, np.ndarray]]) -> int:
        t0 = self.telemetry.clock()
        with self._write_lock():
            n = self._apply_inner(batches)
        self._h_apply_s.observe(self.telemetry.clock() - t0)
        return n

    def _apply_inner(self, batches: List[Dict[str, np.ndarray]]) -> int:
        b = {k: np.concatenate([np.asarray(bb[k]) for bb in batches])
             for k in batches[0]}
        n_in = len(b["fid"])
        if self.telemetry.enabled and n_in:
            self.telemetry.event_stage("apply", int(b["seq"].max()))

        facts = self._coalesce(b)
        if facts is None:
            # nothing survived coalescing (e.g. all-OPEN with filtering
            # on): the watermark advances, the readable state does not
            seq = int(b["seq"].max())
            self._advance_watermark(seq)
            self._notify_applied(seq, mutated=False)
            return n_in

        # a fid the state manager knows as a directory stays one even when
        # this batch's events omit the flag (e.g. a bare RENME on a dir)
        facts["is_dir"] |= np.fromiter(
            (self._is_dir.get(int(f), False) for f in facts["fid"]),
            bool, len(facts["fid"]))

        # rename override: snapshot OLD paths of live descendants BEFORE
        # the fact fold moves the subtree (paper §IV-B2 rule 3)
        ren_dirs_sel = facts["renamed"] & facts["is_dir"]
        old_desc = self._live_descendant_paths(
            facts["fid"][ren_dirs_sel], facts["seq"][ren_dirs_sel])
        # stats + subjects of to-be-deleted fids, read before the fold:
        # the tombstone must hit the path the record is indexed under
        # (pre-rename), and the counting decrement needs the old slots
        dead = facts["dead"]
        dead_fids = facts["fid"][dead]
        pre_resolve = self._make_resolver()
        dead_paths = [pre_resolve(int(f)) for f in dead_fids]
        # owner of the dying record: state-manager stat, else the indexed
        # record itself (register_tree handoff), else zeros
        dead_prev = [self._stat.get(int(f)) or self._record_fields(p) or {}
                     for f, p in zip(dead_fids, dead_paths)]
        # first event for a fid the snapshot indexed (register_tree
        # handoff): seed its stat from the record so sparse events merge
        # onto the scanned values instead of zeros
        for f in facts["fid"][facts["alive"] & ~facts["created"]]:
            fi = int(f)
            if fi not in self._stat and fi in self._parent:
                rec = self._record_fields(pre_resolve(fi))
                if rec:
                    self._stat[fi] = rec
        # ownership facts on already-known records: capture the
        # pre-batch owner BEFORE the fold, so a chown MOVES the count
        # between principals (the enter/leave deltas alone would strand
        # it on the old owner — and, worse, drive the old owner's exact
        # count to zero and ghost-drop a still-live principal)
        own_rows = np.nonzero((facts["has_uid"] | facts["has_gid"])
                              & facts["alive"] & ~facts["created"]
                              & ~facts["is_dir"])[0]
        pre_own: Dict[int, tuple] = {}
        for i in own_rows:
            fi = int(facts["fid"][i])
            st = self._stat.get(fi)
            if st is not None:
                pre_own[fi] = (int(st.get("uid", 0)),
                               int(st.get("gid", 0)))
        # FILE renames move a single subject: remember the old path now,
        # tombstone it after the fold (dir renames go via old_desc)
        ren_files = facts["renamed"] & ~facts["is_dir"] & facts["alive"]
        renf_fids = facts["fid"][ren_files]
        renf_old = [pre_resolve(int(f)) for f in renf_fids]
        renf_seq = facts["seq"][ren_files]
        # rollup moves need the renamed dirs' OWN old paths (pre-fold);
        # dirs also created this batch never existed at an old path
        ren_moved = ren_dirs_sel & facts["alive"] & ~facts["created"]
        rend_fids = facts["fid"][ren_moved]
        rend_old = [pre_resolve(int(f)) for f in rend_fids]

        self._fold_facts(facts)

        # resolve live subjects AFTER the fold (paths reflect the new tree)
        resolve = self._make_resolver()
        up = facts["alive"] & ~facts["is_dir"]
        up_fids = facts["fid"][up]
        up_paths = [resolve(int(f)) for f in up_fids]
        up_vers = facts["seq"][up].copy()
        # chunk-invariant versions: a subject under a dir renamed IN THIS
        # batch carries the rename's seq when newer than its own last
        # event — exactly the version the repath override would stamp if
        # the rename had arrived in a later batch. Without this, the
        # durable pipeline's replay (which re-chunks the stream) could
        # recover records at different versions than the uninterrupted
        # run (DESIGN.md §10.2).
        ren_seq_of = {int(f): int(s) for f, s in
                      zip(facts["fid"][ren_dirs_sel],
                          facts["seq"][ren_dirs_sel])}
        if ren_seq_of:
            memo_rs: Dict[int, int] = {}

            def anc_rename_seq(d: int) -> int:
                chain = []
                best = 0
                on_walk = set()
                while d >= 0 and d not in memo_rs and d not in on_walk:
                    on_walk.add(d)
                    chain.append(d)
                    d = self._parent.get(d, -1)
                best = memo_rs.get(d, 0) if d >= 0 else 0
                for c in reversed(chain):
                    best = max(best, ren_seq_of.get(c, 0))
                    memo_rs[c] = best
                return best

            for i, f in enumerate(up_fids):
                rs = anc_rename_seq(self._parent.get(int(f), -1))
                if rs > up_vers[i]:
                    up_vers[i] = rs
        # columns from the MERGED fact tables (a sparse batch inherits the
        # fields it didn't carry from earlier events / the stored record)
        up_stats = [self._stat.get(int(f), {}) for f in up_fids]
        up_uid = np.array([s.get("uid", 0) for s in up_stats], np.int32)
        up_gid = np.array([s.get("gid", 0) for s in up_stats], np.int32)
        up_size = np.array([s.get("size", 0.0) for s in up_stats],
                           np.float32)
        up_mtime = np.array([s.get("mtime", 0.0) for s in up_stats],
                            np.float32)

        dead_in_batch = frozenset(
            int(f) for f in facts["fid"][facts["dead"] | facts["cancelled"]])
        re_paths, re_fields = self._repath(old_desc, resolve, dead_in_batch)

        # primary index: vectorized columnar upserts + tombstones
        fields = {
            "path_hash": np.array([md.path_hash(p) for p in up_paths],
                                  np.uint32),
            "type": np.full(len(up_paths), md.TYPE_FILE, np.int32),
            "uid": up_uid,
            "gid": up_gid,
            "size": up_size,
            "mtime": up_mtime,
            "atime": up_mtime,
            "ctime": up_mtime,
        }
        new_mask = self.primary.upsert_batch(up_paths, fields, up_vers)
        count_jobs = [(up_paths, up_uid, up_gid, +1.0, new_mask)]
        # chown on a record that stayed live: -1 at the old principal
        # streams, +1 at the new (the dir-prefix components cancel
        # exactly, so only the uid/gid principals actually move)
        moved_own = [i for i, f in enumerate(up_fids)
                     if int(f) in pre_own and not new_mask[i]
                     and (int(up_uid[i]), int(up_gid[i]))
                     != pre_own[int(f)]]
        if moved_own:
            mv_paths = [up_paths[i] for i in moved_own]
            sel = np.ones(len(moved_own), bool)
            count_jobs.append((
                mv_paths,
                np.array([pre_own[int(up_fids[i])][0]
                          for i in moved_own], np.int32),
                np.array([pre_own[int(up_fids[i])][1]
                          for i in moved_own], np.int32),
                -1.0, sel))
            count_jobs.append((mv_paths, up_uid[moved_own],
                               up_gid[moved_own], +1.0, sel))
        if re_paths:
            re_vers = np.asarray(re_paths["vers"], np.int64)
            re_new = self.primary.upsert_batch(re_paths["new"], re_fields,
                                               re_vers)
            re_dead = self.primary.delete_batch(re_paths["old"], re_vers)
            count_jobs.append((re_paths["new"], re_fields["uid"],
                               re_fields["gid"], +1.0, re_new))
            count_jobs.append((re_paths["old"], re_fields["uid"],
                               re_fields["gid"], -1.0, re_dead))
            self.metrics["repathed"] += len(re_paths["new"])
        del_mask = self.primary.delete_batch(dead_paths, facts["seq"][dead])
        if len(dead_paths):
            uidd = np.array([s.get("uid", 0) for s in dead_prev], np.int32)
            gidd = np.array([s.get("gid", 0) for s in dead_prev], np.int32)
            count_jobs.append((dead_paths, uidd, gidd, -1.0, del_mask))
        # file-rename tombstones: old subject dies at the rename's seq
        moved = [i for i, (f, o) in enumerate(zip(renf_fids, renf_old))
                 if resolve(int(f)) != o]
        mv_old: List[str] = []
        if moved:
            mv_old = [renf_old[i] for i in moved]
            mv_stats = [self._stat.get(int(renf_fids[i]))
                        or self._record_fields(renf_old[i]) or {}
                        for i in moved]
            mv_dead = self.primary.delete_batch(
                mv_old, renf_seq[moved])
            count_jobs.append((
                mv_old,
                np.array([s.get("uid", 0) for s in mv_stats], np.int32),
                np.array([s.get("gid", 0) for s in mv_stats], np.int32),
                -1.0, mv_dead))
            self.metrics["repathed"] += len(mv_old)

        if self.hierarchy is not None:
            self._publish_hierarchy(facts, resolve, dead_fids, dead_paths,
                                    mv_old, rend_fids, rend_old, up_paths,
                                    re_paths)

        if self.cfg.update_aggregates:
            self._apply_aggregates(count_jobs, up_paths, up_uid, up_gid,
                                   up_size, up_mtime, new_mask)

        self.metrics["applied"] += n_in
        self.metrics["upserts"] += len(up_paths)
        self.metrics["tombstones"] += int(del_mask.sum())
        self.metrics["cancelled"] += int(facts["cancelled"].sum())
        self.metrics["applies"] += 1
        seq = int(b["seq"].max())
        self._advance_watermark(seq)
        self._notify_applied(seq, mutated=True)
        return n_in

    def _advance_watermark(self, seq: int) -> None:
        self.watermark.applied_seq = max(self.watermark.applied_seq, seq)
        self.watermark.pending = self._buffered
        self.watermark.last_apply_time = self.clock()
        self.watermark.applied_batches += 1
        self._g_applied_seq.set(self.watermark.applied_seq)
        self._g_pending.set(self.watermark.pending)
        self.telemetry.event_visible(self.watermark.applied_seq)

    def _coalesce(self, b: Dict[str, np.ndarray]) -> Optional[Dict]:
        """Rules 1+2 on the host: last event per fid is its representative;
        per-fid facts via last-write-wins scatters over the (fid, seq)
        sorted view. Returns per-UNIQUE-fid arrays."""
        etype = b["etype"]
        valid = np.ones(len(etype), bool)
        if self.cfg.filter_opens:
            valid &= etype != ev.E_OPEN
        if not valid.any():
            return None
        b = {k: v[valid] for k, v in b.items()}
        order = np.lexsort((b["seq"], b["fid"]))
        b = {k: v[order] for k, v in b.items()}
        fid = b["fid"]
        etype = b["etype"]
        uf, inv = np.unique(fid, return_inverse=True)
        m = len(uf)

        def last(values, mask=None, init=0):
            out = np.full(m, init, np.asarray(values).dtype)
            if mask is None:
                out[inv] = values           # sorted by seq -> last wins
            else:
                out[inv[mask]] = values[mask]
            return out

        last_et = last(etype)
        seq = last(b["seq"])
        created = np.zeros(m, bool)
        np.logical_or.at(created, inv,
                         (etype == ev.E_CREAT) | (etype == ev.E_MKDIR))
        renamed = np.zeros(m, bool)
        np.logical_or.at(renamed, inv, etype == ev.E_RENME)
        is_dir = np.zeros(m, bool)
        np.logical_or.at(is_dir, inv, b["is_dir"] > 0)

        parent_eff = np.where(b["new_parent_fid"] >= 0,
                              b["new_parent_fid"], b["parent_fid"])
        parent = last(parent_eff, parent_eff >= 0, init=-1)
        # stat facts: stat-carrying rows win; else the last row that
        # carried a nonzero value (Lustre events are stat-free, so e.g. an
        # UNLNK row's zero uid must not clobber the CREAT's)
        hs = b["has_stat"] > 0
        any_stat = np.zeros(m, bool)
        np.logical_or.at(any_stat, inv, hs)    # ANY row, not just the last

        def any_pos(field):
            out = np.zeros(m, bool)
            np.logical_or.at(out, inv, b[field] > 0)
            return out

        def fact(field):
            v = b[field]
            return np.where(any_stat, last(v, hs), last(v, v > 0))

        size = fact("size")
        mtime = fact("mtime")
        # ownership: stat rows may omit uid/gid (e.g. a bare WRITE), so a
        # chown is whichever row last carried a nonzero owner
        uid = last(b["uid"], b["uid"] > 0)
        gid = last(b["gid"], b["gid"] > 0)
        # which facts this batch actually carried (events are sparse: a
        # batch with no stat/owner info must not clobber stored facts)
        has_size = any_stat | any_pos("size")
        has_mtime = any_stat | any_pos("mtime")
        has_uid = any_pos("uid")
        has_gid = any_pos("gid")

        is_del = (last_et == ev.E_UNLNK) | (last_et == ev.E_RMDIR)
        cancelled = is_del & created
        return {
            "fid": uf, "seq": seq, "parent": parent,
            "size": size, "mtime": mtime, "uid": uid, "gid": gid,
            "is_dir": is_dir, "renamed": renamed, "created": created,
            "alive": ~is_del, "dead": is_del & ~created,
            "cancelled": cancelled,
            "has_stat": any_stat,
            "has_size": has_size, "has_mtime": has_mtime,
            "has_uid": has_uid, "has_gid": has_gid,
        }

    def _fold_facts(self, facts: Dict) -> None:
        """Apply coalesced facts to the host fid tables (the paper's state
        manager; dict ops only — O(unique fids))."""
        for i, f in enumerate(facts["fid"]):
            f = int(f)
            if facts["dead"][i] or facts["cancelled"][i]:
                self._stat.pop(f, None)
                old_p = self._parent.get(f)
                if old_p is not None:
                    self._children.get(old_p, set()).discard(f)
                continue
            p = int(facts["parent"][i])
            if p >= 0:
                old_p = self._parent.get(f)
                if old_p is not None and old_p != p:
                    self._children.get(old_p, set()).discard(f)
                self._parent[f] = p
                self._children.setdefault(p, set()).add(f)
            if facts["is_dir"][i]:
                self._is_dir[f] = True
            st = self._stat.setdefault(
                f, {"size": 0.0, "mtime": 0.0, "uid": 0, "gid": 0})
            if facts["has_size"][i]:
                st["size"] = float(facts["size"][i])
            if facts["has_mtime"][i]:
                st["mtime"] = float(facts["mtime"][i])
                # snapshot-seeded access times are stale once an event
                # touches the record; drop them so downstream writers
                # fall back to the atime=ctime=mtime event convention
                st.pop("atime", None)
                st.pop("ctime", None)
            if facts["has_uid"][i]:
                st["uid"] = int(facts["uid"][i])
            if facts["has_gid"][i]:
                st["gid"] = int(facts["gid"][i])

    def _make_resolver(self) -> Callable[[int], str]:
        memo: Dict[int, str] = {}

        def resolve(f: int) -> str:
            # iterative parent walk: collect the unmemoized ancestor
            # chain, then fill memo root-to-leaf (no recursion cap, so
            # legitimately deep trees resolve; only a TRUE parent cycle
            # — corrupt changelog, a real FS rejects subtree-into-itself
            # renames — anchors at a loud marker instead of looping)
            chain = []
            on_walk = set()
            cur = f
            while True:
                got = memo.get(cur)
                if got is not None:
                    prefix = got
                    break
                if cur in on_walk:
                    self.metrics["unresolved"] += 1
                    prefix = f"/#cycle#{cur}"
                    break
                on_walk.add(cur)
                name = self._name.get(cur)
                if name is None:
                    # fid never registered (e.g. scanned by a snapshot
                    # before this ingestor attached): subjects resolved
                    # through this fallback cannot match the snapshot-
                    # loaded record — count it loudly; deployments
                    # should register_tree() first
                    self.metrics["unresolved"] += 1
                    name = f"#{cur}"
                chain.append((cur, name))
                p = self._parent.get(cur, -1)
                if p < 0:
                    prefix = ""
                    break
                cur = p
            for fid, name in reversed(chain):
                prefix = prefix + "/" + name
                memo[fid] = prefix
            return memo[f] if chain else prefix
        return resolve

    def register_tree(self, parents: Dict[int, int], names: Dict[int, str],
                      is_dir: Optional[Dict[int, bool]] = None) -> None:
        """Bootstrap the state manager with an existing fid -> (parent,
        name) tree — the snapshot -> event handoff (paper §IV-B3: the
        scanner records fids, so a changelog event on a pre-scan file
        resolves to the same subject the snapshot indexed). Without this,
        events for unknown fids resolve to '#fid' fallback subjects and
        cannot touch snapshot-loaded records (metrics['unresolved']).
        Pair with ``seed_counts`` to keep the aggregate counting matrix
        exact over the snapshot-loaded records too (``counts_exact``)."""
        self._tree_registered = True
        self._name.update(names)
        for f, p in parents.items():
            self._parent[f] = p
            self._children.setdefault(p, set()).add(f)
        for f, d in (is_dir or {}).items():
            if d:
                self._is_dir[f] = True
        # the hierarchy half of the handoff: re-seed the rollup tree
        # from the registered dirs + the primary's live records (the
        # bulk snapshot ingest just invalidated it)
        self._seed_hierarchy()

    def _live_descendant_paths(self, dir_fids: np.ndarray,
                               dir_seqs: np.ndarray
                               ) -> Dict[int, Tuple[str, int]]:
        """Old subjects of every FILE under the given renamed dirs,
        resolved against the pre-rename tree, each tagged with the seq
        of the rename that moves it (the max over its renamed ancestors
        — that PER-EVENT seq is the repath's version, so replaying the
        same events in different batch groupings lands identical
        versions: the durable pipeline's chunk-invariance contract,
        DESIGN.md §10.2). Includes files known only through
        ``register_tree`` (no event-derived stat yet) — their index
        record is the source of truth at repath time."""
        if len(dir_fids) == 0:
            return {}
        resolve = self._make_resolver()
        out: Dict[int, Tuple[str, int]] = {}
        stack = [(int(f), int(s)) for f, s in zip(dir_fids, dir_seqs)]
        seen: Dict[int, int] = {}
        while stack:
            d, seq = stack.pop()
            if seen.get(d, -1) >= seq:
                continue
            seen[d] = seq
            for c in self._children.get(d, ()):
                if self._is_dir.get(c):
                    stack.append((c, seq))
                else:
                    got = out.get(c)
                    out[c] = (resolve(c) if got is None else got[0],
                              seq if got is None else max(got[1], seq))
        return out

    def _record_fields(self, path: str) -> Optional[Dict[str, float]]:
        """Owner/stat of the indexed record at ``path`` (live or not) —
        the fallback fact source for fids the state manager only knows
        via register_tree. Routes through the index's ``get_record`` so
        sharded primaries resolve it in the owning shard. Includes
        atime/ctime so a repath can move a snapshot-loaded record
        without zeroing its access times."""
        return self.primary.get_record(
            path, keys=("uid", "gid", "size", "mtime", "atime", "ctime"))

    def _repath(self, old_desc: Dict[int, Tuple[str, int]],
                resolve: Callable[[int], str],
                dead_in_batch: frozenset):
        """Rename override on the index: move descendants whose subject
        changed (old tombstone + new upsert carrying the stored stat, or
        the indexed record's own fields for register_tree-only fids).
        Each move carries the triggering rename's OWN seq as its version
        (``old_desc`` values are (old_path, rename_seq))."""
        if not old_desc:
            return {}, {}
        olds, news, stats, vers = [], [], [], []
        for f, (old_path, seq) in old_desc.items():
            if f in dead_in_batch:      # deleted in this same batch
                continue
            st = self._stat.get(f) or self._record_fields(old_path)
            if st is None:              # never indexed, nothing to move
                continue
            new_path = resolve(f)
            if new_path == old_path:
                continue
            olds.append(old_path)
            news.append(new_path)
            stats.append(st)
            vers.append(seq)
        if not news:
            return {}, {}
        mtimes = np.array([s.get("mtime", 0.0) for s in stats], np.float32)
        fields = {
            "path_hash": np.array([md.path_hash(p) for p in news], np.uint32),
            "type": np.full(len(news), md.TYPE_FILE, np.int32),
            "uid": np.array([s.get("uid", 0) for s in stats], np.int32),
            "gid": np.array([s.get("gid", 0) for s in stats], np.int32),
            "size": np.array([s.get("size", 0.0) for s in stats], np.float32),
            "mtime": mtimes,
            # a repath moves the record, it does not touch it: carry the
            # stored access times (event-derived records fall back to the
            # mtime convention, DESIGN.md §6.2)
            "atime": np.array([s.get("atime", s.get("mtime", 0.0))
                               for s in stats], np.float32),
            "ctime": np.array([s.get("ctime", s.get("mtime", 0.0))
                               for s in stats], np.float32),
        }
        return {"old": olds, "new": news, "vers": vers}, fields

    # -- aggregate pipeline (device) -----------------------------------------

    def _principal_rows(self, paths: List[str],
                        uid: np.ndarray, gid: np.ndarray):
        """(streams, sids): principal slot streams exactly like snapshot
        preprocessing — uid slot, gid slot, and one dir-prefix slot per
        depth in [dir_min, dir_max] (slot = FNV hash of the ancestor dir's
        path, computed from the resolved parent chain)."""
        cfg = self.pcfg
        n = len(paths)
        uid_slot = uid.astype(np.int64) % cfg.n_users
        gid_slot = cfg.n_users + gid.astype(np.int64) % cfg.n_groups
        base = cfg.n_users + cfg.n_groups
        levels = cfg.dir_max - cfg.dir_min + 1
        dir_slots = np.full((n, levels), -1, np.int64)
        memo: Dict[str, np.ndarray] = {}
        for i, p in enumerate(paths):
            dpath = p.rsplit("/", 1)[0]
            got = memo.get(dpath)
            if got is None:
                comps = [c for c in dpath.split("/") if c]
                got = np.full(levels, -1, np.int64)
                for li, depth in enumerate(range(cfg.dir_min,
                                                 cfg.dir_max + 1)):
                    if depth < len(comps):
                        anc = "/" + "/".join(comps[:depth + 1])
                        got[li] = base + md.path_hash(anc) % cfg.n_dirs
                memo[dpath] = got
            dir_slots[i] = got
        sids = np.fromiter((md.crc32_shard(p.encode(), cfg.n_shards)
                            for p in paths), np.int64, n)
        streams = [(uid_slot, np.ones(n, np.float32)),
                   (gid_slot, np.ones(n, np.float32))]
        for li in range(levels):
            pid = dir_slots[:, li]
            streams.append((np.maximum(pid, 0),
                            (pid >= 0).astype(np.float32)))
        return streams, sids

    def _apply_aggregates(self, count_jobs, up_paths, up_uid, up_gid,
                          up_size, up_mtime, new_mask) -> None:
        """Device-side aggregate maintenance for one applied batch: counting
        deltas (±1 per subject entering/leaving the index, including
        rename moves between dir principals) and sketch observations for
        newly-seen subjects, then republish touched principals."""
        cfg = self.pcfg
        touched: set = set()

        for paths, uid, gid, sign, sel in count_jobs:
            if not np.any(sel):
                continue
            paths = [p for p, s in zip(paths, sel) if s]
            streams, sids = self._principal_rows(paths, uid[sel], gid[sel])
            pid_cat = np.concatenate([p for p, _ in streams])
            w_cat = np.concatenate([w for _, w in streams]) * sign
            sid_cat = np.tile(sids, len(streams))
            npad = _bucket(len(pid_cat), self.cfg.pad_to)
            delta = self._count_step(
                jnp.asarray(_pad(pid_cat, npad)),
                jnp.asarray(_pad(sid_cat, npad)),
                jnp.asarray(_pad(w_cat, npad)))
            self.counts += np.asarray(delta, np.float32)
            touched.update(np.unique(pid_cat[w_cat != 0]).tolist())

        # sketch observations: once per newly-seen subject (additive-only;
        # updates/deletes reach quantiles at the next snapshot rebuild)
        sel = new_mask
        if np.any(sel):
            paths = [p for p, s in zip(up_paths, sel) if s]
            streams, _ = self._principal_rows(paths, up_uid[sel],
                                              up_gid[sel])
            mt = up_mtime[sel]
            vals = np.stack([up_size[sel],
                             mt, mt, mt])          # size, atime, ctime, mtime
            pid_cat = np.concatenate([p for p, _ in streams])
            w_cat = np.concatenate([w for _, w in streams])
            vals_cat = np.tile(vals, (1, len(streams)))
            npad = _bucket(len(pid_cat), self.cfg.pad_to)
            vals_p = np.stack([_pad(vals_cat[a], npad)
                               for a in range(vals_cat.shape[0])])
            apply_fn = (_sketch_apply_kernel if self.cfg.use_kernel
                        else _sketch_apply_ref)
            self._sketch_state = apply_fn(
                cfg.sketch, self._sketch_state, jnp.asarray(vals_p),
                jnp.asarray(_pad(pid_cat, npad).astype(np.int32)),
                jnp.asarray(_pad(w_cat, npad)))
            self.metrics["sketch_rows"] += int(w_cat.sum())
            touched.update(np.unique(pid_cat[w_cat != 0]).tolist())

        if touched:
            # exact counts (when the matrix speaks for the whole index,
            # see counts_exact) override the sketch's additive-only
            # count, so a principal whose last record died in this batch
            # is dropped from the aggregate index, not left as a ghost
            self.aggregate.from_sketch_state(
                cfg.sketch, self._sketch_state, self._principal_names,
                only=sorted(int(t) for t in touched),
                counts=self._exact_counts())

    def _count_step(self, pids, sids, weights):
        if self.cfg.use_kernel:
            from repro.kernels.segstats import ops as seg_ops
            seg = seg_ops.segstats(pids, sids, weights, weights,
                                   self.pcfg.n_principals,
                                   self.pcfg.n_shards)
            return seg["counts"]
        return _count_apply_ref(pids.astype(jnp.int32),
                                sids.astype(jnp.int32),
                                weights.astype(jnp.float32),
                                self.pcfg.n_principals, self.pcfg.n_shards)
