"""MVCC snapshot views over the primary index (DESIGN.md §12).

``PrimaryIndex.snapshot()`` / ``ShardedPrimaryIndex.snapshot()`` pin one
of these under the index write lock. A view is cheap — O(#arenas)
references, no copies: the index marks every arena *shared* at pin time
and its mutators copy-on-first-write any shared arena before touching it
(``PrimaryIndex._unshare``), so the view keeps answering from the frozen
originals while ingest proceeds. Wholesale arena rebinds (capacity
growth, compaction, restore) publish fresh arrays and leave the pinned
ones untouched, so a view survives every mutation class — including
compaction renumbering slots and checkpoints restoring older state.

Views are refcounted by the mutation epoch they pinned
(``PrimaryIndex._snap_refs``): ``close()`` — idempotent; views are
context managers — drops the view's array references and decrements the
pin, and once no pin remains the index stops COW-copying entirely.
``snapshot_stats()`` audits open pins (the leak check's probe).

Read surface: the PrimaryIndex view methods (``live`` / ``live_paths`` /
``lookup`` / ``get_record`` / ``__len__``) with identical semantics and
row order, evaluated against the pinned arenas — so a ``QueryEngine``
runs against a view unmodified, planner included (``self.discovery`` is
a ``discovery.SnapshotDiscovery`` pinned alongside, and the sharded view
exposes ``.shards`` for ``discovery.discovery_shards``). Point probes
(``lookup`` / ``get_record``) touch the live slot map — append-only for
a given map object, but probed under the index lock because the sharded
``HashSlotMap`` folds its overlay during probes — then filter out slots
assigned after the pin; everything else is lock-free reads of frozen
arrays.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core import metadata as md
from repro.core.index import PrimaryIndex


class IndexSnapshot:
    """Read-only view of one ``PrimaryIndex`` pinned at a mutation
    epoch. Constructed by ``PrimaryIndex.snapshot()`` UNDER the index
    write lock — never directly."""

    def __init__(self, index: PrimaryIndex, freshness: Optional[Dict] = None):
        self._index = index
        self.n = len(index.slot_map)           # slots assigned at pin
        self.columns: Dict[str, np.ndarray] = dict(index.columns)
        self.paths = index.paths
        self.version = index.version
        self.alive = index.alive
        self._slot_map = index.slot_map
        self.tombstone_floor = index.tombstone_floor
        self.mutation_epoch = index.mutation_epoch
        #: uninterpreted freshness mark pinned by the serving tier (the
        #: ingest watermark the pinned state reflects)
        self.freshness_mark = freshness
        d = index.discovery
        if d is not None:
            from repro.core.discovery import SnapshotDiscovery
            self.discovery = SnapshotDiscovery(self, d)
        else:
            self.discovery = None
        self._closed = False

    # -- lifecycle -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        """Release this view's pin (idempotent). Drops every array
        reference so the frozen arenas become collectable as soon as no
        other view pins them — closing snapshots is what returns COW
        memory."""
        if self._closed:
            return
        self._closed = True
        self.columns = {}
        self.paths = None
        self.version = None
        self.alive = None
        self._slot_map = None
        self.discovery = None
        self._index._release_snapshot(self.mutation_epoch)

    def __enter__(self) -> "IndexSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read surface (PrimaryIndex view semantics, pinned arenas) -----------

    def _probe(self, path: str) -> Optional[int]:
        """Slot of ``path`` as of the pin: the live slot map is
        append-only per map object (compaction swaps in a NEW map; the
        pinned reference stays valid), so a probe under the index lock
        plus the ``slot < n`` filter yields exactly the pin-time
        assignment. The lock matters for the sharded ``HashSlotMap``,
        whose probes fold a write overlay."""
        with self._index.write_lock():
            slot = self._slot_map.get(path)
        if slot is None or slot >= self.n:
            return None
        return slot

    def lookup(self, path: str) -> Optional[Dict[str, float]]:
        slot = self._probe(path)
        if slot is None or not self.alive[slot]:
            return None
        out = {k: v[slot].item() for k, v in self.columns.items()}
        out["path"] = path
        out["version"] = int(self.version[slot])
        return out

    def get_record(self, path: str, keys: Sequence[str] = (
            "uid", "gid", "size", "mtime")) -> Optional[Dict[str, float]]:
        slot = self._probe(path)
        if slot is None:
            return None
        return {k: self.columns[k][slot].item()
                for k in keys if k in self.columns}

    def live(self, copy: bool = True) -> Dict[str, np.ndarray]:
        """``PrimaryIndex.live`` against the pinned arenas (the arrays
        are frozen, so ``copy=False`` views are safe for the lifetime
        of the snapshot, not just until the next mutation)."""
        n = self.n
        mask = self.alive[:n]
        if mask.all():
            out = {k: v[:n].copy() if copy else v[:n]
                   for k, v in self.columns.items()}
            out["path"] = self.paths[:n].copy() if copy else self.paths[:n]
            m = n
        else:
            out = {k: v[:n][mask] for k, v in self.columns.items()}
            out["path"] = self.paths[:n][mask]
            m = int(mask.sum())
        for k, dt in PrimaryIndex.STANDARD_COLUMNS.items():
            if k not in out:
                out[k] = np.zeros(m, dt)
        return out

    def live_paths(self, copy: bool = True) -> np.ndarray:
        n = self.n
        mask = self.alive[:n]
        if mask.all():
            return self.paths[:n].copy() if copy else self.paths[:n]
        return self.paths[:n][mask]

    def __len__(self) -> int:
        return int(self.alive[:self.n].sum())


class ShardedIndexSnapshot:
    """Read-only view of a ``ShardedPrimaryIndex``: one pinned
    ``IndexSnapshot`` per shard (all pinned under the sharded index's
    top-level write lock, so they are mutually consistent), merged with
    the sharded index's own scatter-gather semantics — shard-major row
    order, hash-routed point probes. ``shards`` is the per-shard view
    list ``discovery.discovery_shards`` duck-types."""

    def __init__(self, index, shards, freshness: Optional[Dict] = None):
        self._index = index
        self.shards = list(shards)
        self.n_shards = len(self.shards)
        self.freshness_mark = freshness
        #: the layout-wide epoch is the per-shard sum, mirroring the
        #: serving tier's data-version probe (query_service.py)
        self.mutation_epoch = sum(s.mutation_epoch for s in self.shards)
        self._closed = False

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for s in self.shards:
            s.close()
        self.shards = []

    def __enter__(self) -> "ShardedIndexSnapshot":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read surface (ShardedPrimaryIndex view semantics) -------------------

    def shard_of(self, path: str) -> int:
        return md.path_hash(path) % self.n_shards

    def lookup(self, path: str) -> Optional[Dict[str, float]]:
        return self.shards[self.shard_of(path)].lookup(path)

    def get_record(self, path: str, keys: Sequence[str] = (
            "uid", "gid", "size", "mtime")) -> Optional[Dict[str, float]]:
        return self.shards[self.shard_of(path)].get_record(path, keys)

    def live(self) -> Dict[str, np.ndarray]:
        """Scatter-gather merge, byte-identical to
        ``ShardedPrimaryIndex.live()`` over the same state: shard-major
        row order, columns only some shards carry zero-filled
        elsewhere. Per-shard views are copy-free — pinned arenas are
        frozen, and the concatenate materializes anyway."""
        views = [s.live(copy=False) for s in self.shards]
        counts = [len(v["path"]) for v in views]
        keys = {}
        for v in views:
            for k, col in v.items():
                keys.setdefault(k, col.dtype)
        out = {}
        for k, dt in keys.items():
            out[k] = np.concatenate(
                [v[k] if k in v else np.zeros(c, dt)
                 for v, c in zip(views, counts)])
        return out

    def live_paths(self) -> np.ndarray:
        return np.concatenate([s.live_paths(copy=False)
                               for s in self.shards])

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)
