"""Three-term roofline from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, all terms PER DEVICE per step on TPU v5e:

  compute    = max(mxu_flops / 197e12, vpu_flops / 3.9e12)   [s]
  memory     = (argument + output + 2*temp bytes) / 819e9     [s]
  collective = wire_bytes / 50e9                              [s]

- FLOPs are the trip-count-corrected HLO counts (analysis/hlocost.py); the
  VPU term matters for SSM/RG-LRU cells whose recurrences are elementwise.
- The memory model: arguments are read once (params/opt/KV-cache/batch),
  outputs written once, every live temp written+read once. It deliberately
  excludes XLA:CPU's fusion-boundary noise (a TPU keeps those blocks in
  VMEM); hlocost.hbm_bytes is the pessimistic upper bound where available.
- wire_bytes uses ring-algorithm costs (2(g-1)/g for all-reduce etc.).

step_time ~= max(terms) (perfect overlap) .. sum(terms) (no overlap).
Roofline fraction := compute / sum(terms)  — the conservative (no-overlap)
fraction of peak the cell achieves; 1.0 = pure compute-bound at peak.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

PEAK_MXU = 197e12      # bf16 FLOP/s per chip (v5e)
PEAK_VPU = 3.9e12      # f32 vector FLOP/s per chip (8x128x4 @ 940 MHz)
HBM_BW = 819e9         # B/s per chip
ICI_BW = 50e9          # B/s per link
HBM_CAP = 16 * 2 ** 30


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok":
        return None
    mem = rec["memory"]
    mem_bytes = (mem["argument_bytes"] + mem["output_bytes"]
                 + 2 * mem["temp_bytes"])
    compute_mxu = rec["mxu_flops_per_device"] / PEAK_MXU
    compute_vpu = rec["vpu_flops_per_device"] / PEAK_VPU
    compute = max(compute_mxu, compute_vpu)
    memory = mem_bytes / HBM_BW
    coll = rec.get("coll_wire_bytes", 0.0) / ICI_BW
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    total = sum(terms.values())
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute, "compute_mxu_s": compute_mxu,
        "compute_vpu_s": compute_vpu,
        "memory_s": memory, "collective_s": coll,
        "dominant": dominant,
        "step_time_lo_s": max(terms.values()),
        "step_time_hi_s": total,
        "roofline_fraction": compute / total if total > 0 else 0.0,
        "mem_bytes_per_device": mem_bytes,
        "fits_hbm": (mem["argument_bytes"] + mem["output_bytes"]
                     - mem.get("alias_bytes", 0) + mem["temp_bytes"]) <= HBM_CAP,
        "hbm_used_gib": (mem["argument_bytes"] + mem["output_bytes"]
                         - mem.get("alias_bytes", 0)
                         + mem["temp_bytes"]) / 2 ** 30,
        # persistent working set (params/opt/caches/batch, no temps): the
        # TPU-true usage lies between this and hbm_used_gib, whose temps
        # include XLA:CPU's f32 staging copies of every bf16 weight
        "hbm_lo_gib": (mem["argument_bytes"] + mem["output_bytes"]
                       - mem.get("alias_bytes", 0)) / 2 ** 30,
    }
    # model-FLOPs utilisation bound: 6*N_active*D / (chips * HLO_FLOPs)
    if rec.get("active_param_count") and rec["shape"] == "train_4k":
        tokens = {"train_4k": 256 * 4096}.get(rec["shape"], 0)
        model_flops = 6 * rec["active_param_count"] * tokens
        hlo_global = rec["mxu_flops_per_device"] * rec["n_chips"]
        out["model_flops"] = model_flops
        out["model_over_hlo"] = model_flops / hlo_global if hlo_global else 0
        # projected MFU (no-overlap): useful flops / (step_time * peak)
        out["projected_mfu"] = (model_flops / rec["n_chips"] / total
                                / PEAK_MXU if total else 0.0)
    return out


def load_records(*paths: str) -> List[Dict]:
    recs = []
    for p in paths:
        try:
            with open(p) as f:
                for line in f:
                    recs.append(json.loads(line))
        except FileNotFoundError:
            pass
    # keep the LAST record per cell key (re-runs supersede)
    by_key = {}
    for r in recs:
        by_key[(r["arch"], r["shape"], r["mesh"], r.get("tag", ""))] = r
    return list(by_key.values())


def table(records: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for rec in records:
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": mesh, "dominant": "SKIP",
                         "reason": rec.get("reason", "")})
            continue
        t = roofline_terms(rec)
        if t:
            rows.append(t)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    return rows


def fmt_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | coll s | dominant | "
           "roofline frac | HBM GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["dominant"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | "
                       f"— | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['hbm_used_gib']:.1f} | {'Y' if r['fits_hbm'] else 'N'} |\n")
    return "".join(out)
