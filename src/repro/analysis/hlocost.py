"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
using ``lax.scan`` (layer stacks, flash-attention chunks, SSD chunks, loss
chunks) is undercounted by the trip count. This module re-derives

  - MXU FLOPs (dot/convolution, x2 multiply-add),
  - VPU FLOPs (elementwise / reduce ops),
  - per-collective byte counts (operand bytes and ring wire-bytes),

by walking the computation call graph (entry -> fusions/calls/while bodies)
and multiplying each computation's cost by the product of enclosing loop
trip counts (XLA records ``known_trip_count`` in while backend_config —
every ``lax.scan`` gets one).

All numbers are PER DEVICE (the module is the SPMD-partitioned program).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred|c64|c128|token)\[([\d,]*)\]")

# one instruction: "  %name = TYPE opcode(operands), attrs"
# TYPE may be a tuple "(f32[..], /*index=5*/ s32[..])" (no nested parens).
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s/*]+?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+(?:\([^)]*\)\s*->.*)?\{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLEE_RE = re.compile(r"(?:body|condition|to_apply|calls)=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUP_RE1 = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_RE2 = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "and", "or", "xor", "not", "negate", "abs", "exponential", "log",
    "log-plus-one", "exponential-minus-one", "tanh", "sqrt", "rsqrt",
    "cbrt", "sine", "cosine", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "is-finite", "select", "compare", "clamp",
    "atan2", "erf", "logistic", "remainder", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "popcnt", "clz",
}
ZERO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "transpose", "copy", "broadcast", "iota", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "reverse",
    "pad", "convert", "after-all", "partition-id", "replica-id",
    "rng-bit-generator", "rng", "copy-start", "copy-done", "bitcast-convert",
    "all-gather-done", "all-reduce-done", "custom-call", "infeed", "outfeed",
    "optimization-barrier", "get-dimension-size", "domain",
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _nelems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _type_bytes_elems(type_str: str) -> Tuple[int, int]:
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = _nelems(dims)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_b, total_e


@dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes (rest of the line)


@dataclass
class CostResult:
    mxu_flops: float = 0.0
    vpu_flops: float = 0.0
    hbm_bytes: float = 0.0     # fusion-aware traffic (see _instr_bytes)
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def flops(self) -> float:
        return self.mxu_flops + self.vpu_flops

    @property
    def coll_operand_bytes(self) -> float:
        return sum(v["bytes_operand"] for v in self.coll.values())

    @property
    def coll_wire_bytes(self) -> float:
        return sum(v["bytes_wire"] for v in self.coll.values())

    def as_dict(self) -> Dict:
        return {
            "mxu_flops": self.mxu_flops,
            "vpu_flops": self.vpu_flops,
            "hbm_bytes": self.hbm_bytes,
            "collectives": self.coll,
            "coll_operand_bytes": self.coll_operand_bytes,
            "coll_wire_bytes": self.coll_wire_bytes,
        }


def _is_comp_header(line: str) -> bool:
    # Computation headers sit at column 0 and end with "{"; instructions are
    # indented. (Headers may contain "=" inside /*index=N*/ comments, so no
    # "=" check.)
    if not line.endswith("{"):
        return False
    return (line.startswith("ENTRY ") or line.startswith("%")
            or bool(re.match(r"^[\w\.\-]+\s*\(", line)))


def parse_computations(hlo: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if _is_comp_header(line):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            comps[cur].append(Instr(mi.group(1), mi.group(2), mi.group(3),
                                    mi.group(4)))
    return comps


def _instr_cost(ins: Instr, types: Dict[str, str]) -> Tuple[float, float]:
    """(mxu_flops, vpu_flops) for one instruction."""
    op = ins.opcode
    if op in ZERO_COST or op.startswith("all-") or op in (
            "while", "conditional", "call", "fusion", "collective-permute",
            "reduce-scatter"):
        return 0.0, 0.0
    out_b, out_e = _type_bytes_elems(ins.type_str)
    if op == "dot":
        mk = _DOT_DIMS_RE.search(ins.rest)
        # operand 0 shape -> contracting dim sizes
        ops = re.findall(r"%([\w\.\-]+)", ins.rest)
        flops = 2.0 * out_e
        if mk and ops:
            lhs_t = types.get(ops[0], "")
            sm = _SHAPE_RE.search(lhs_t)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                for ci in mk.group(1).split(","):
                    if ci != "" and int(ci) < len(dims):
                        flops *= dims[int(ci)]
        return flops, 0.0
    if op == "convolution":
        # approximate: 2 * out_elems * (kernel elems) — rare in this code
        ops = re.findall(r"%([\w\.\-]+)", ins.rest)
        k_e = 1
        if len(ops) >= 2:
            _, k_e = _type_bytes_elems(types.get(ops[1], ""))
        return 2.0 * out_e * max(k_e, 1), 0.0
    if op in ("reduce", "reduce-window"):
        ops = re.findall(r"%([\w\.\-]+)", ins.rest)
        in_e = 0
        if ops:
            _, in_e = _type_bytes_elems(types.get(ops[0], ""))
        return 0.0, float(max(in_e, out_e))
    if op in ("scatter", "gather", "sort", "map", "select-and-scatter"):
        return 0.0, float(out_e)
    if op in ELEMENTWISE:
        return 0.0, float(out_e)
    # unknown op: treat as elementwise
    return 0.0, float(out_e)


def _operand_bytes(ins: Instr, types: Dict[str, str]) -> int:
    total = 0
    for ref in re.findall(r"%([\w\.\-]+)", ins.rest.split("),")[0] + ")"):
        if ref in types:
            b, _ = _type_bytes_elems(types[ref])
            total += b
    return total


_BYTES_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "after-all", "partition-id", "replica-id", "iota",
    "broadcast", "optimization-barrier", "get-dimension-size", "domain",
    "bitcast-convert", "copy-start", "copy-done", "all-gather-done",
    "all-reduce-done",
}


def _instr_bytes(ins: Instr, types: Dict[str, str]) -> float:
    """Fusion-aware HBM traffic model: a fusion region touches its operands
    + result once (internals are register/VMEM-resident); scatter/DUS are
    read-modify-write of the UPDATE extent only (in-place); gathers touch
    ~result-sized slices of their operand. while/call/conditional bodies
    are handled by the walker (recursion x trip count), so cost 0 here."""
    op = ins.opcode
    if op in _BYTES_FREE or op in ("while", "conditional", "call"):
        return 0.0
    out_b, _ = _type_bytes_elems(ins.type_str)
    if op in ("dynamic-update-slice", "scatter"):
        # update operand is the last data operand; approximate with the
        # smallest operand (indices are tiny, update < buffer)
        refs = re.findall(r"%([\w\.\-]+)", ins.rest.split("),")[0] + ")")
        sizes = sorted(_type_bytes_elems(types[r])[0]
                       for r in refs if r in types)
        upd = sizes[-2] if len(sizes) >= 2 else (sizes[0] if sizes else out_b)
        return float(3 * min(upd, out_b))
    if op in ("gather", "dynamic-slice", "slice"):
        return float(2 * out_b)
    if op.startswith("all-") or op in ("collective-permute", "reduce-scatter"):
        return float(out_b + _operand_bytes(ins, types))
    # fusion / dot / convolution / elementwise / reduce / sort / copy ...
    return float(out_b + _operand_bytes(ins, types))


def _effective_operand_bytes(ref: str, types: Dict[str, str],
                             producers: Optional[Dict[str, "Instr"]]) -> int:
    """Operand bytes for a collective, correcting XLA:CPU's bf16->f32
    promotion: when the operand is produced by a convert(-fusion) whose own
    input is narrower (bf16), a TPU build runs the collective at the narrow
    dtype — count those bytes. (Verified in grok HLO: every activation/grad
    all-reduce is f32 wrapping a bf16 dot via %convert_*_fusion.)"""
    b, e = _type_bytes_elems(types.get(ref, ""))
    if not producers or ref not in producers or e == 0:
        return b
    prod = producers[ref]
    if prod.opcode == "convert" or "convert" in prod.name:
        in_sizes = []
        for r2 in re.findall(r"%([\w\.\-]+)", prod.rest.split(")")[0] + ")"):
            if r2 in types:
                b2, e2 = _type_bytes_elems(types[r2])
                if e2:
                    in_sizes.append(b2 / e2)
        if in_sizes and min(in_sizes) < b / e:
            return int(e * min(in_sizes))
    return b


def _collective_cost(ins: Instr, types: Dict[str, str],
                     producers: Optional[Dict[str, "Instr"]] = None
                     ) -> Optional[Tuple[str, int, float]]:
    base = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
    if base not in COLLECTIVES:
        return None
    g = 1
    mg = _GROUP_RE1.search(ins.rest)
    if mg:
        g = int(mg.group(2))
    else:
        mg = _GROUP_RE2.search(ins.rest)
        if mg:
            g = len(mg.group(1).split(","))
    ob = 0
    for ref in re.findall(r"%([\w\.\-]+)", ins.rest.split(")")[0] + ")"):
        if ref in types:
            ob += _effective_operand_bytes(ref, types, producers)
    if ob == 0:
        rb, _ = _type_bytes_elems(ins.type_str)
        if base == "all-gather":
            ob = rb // max(g, 1)
        elif base == "reduce-scatter":
            ob = rb * g
        else:
            ob = rb
    if base == "all-reduce":
        wire = 2.0 * ob * (g - 1) / max(g, 1)
    elif base == "all-gather":
        wire = float(ob) * (g - 1)
    elif base in ("reduce-scatter", "all-to-all"):
        wire = ob * (g - 1) / max(g, 1)
    else:
        wire = float(ob)
    return base, ob, wire


def analyze_hlo(hlo: str, entry: Optional[str] = None,
                bf16_collectives: bool = True) -> CostResult:
    """bf16_collectives: XLA:CPU has no native bf16 matmul, so it promotes
    the whole bf16 dataflow (dots, converts, collectives) to f32; a TPU
    build of the same program communicates activations/grads in bf16. When
    set, f32 collective bytes are counted at 2 B/elem. (Verified on grok:
    every large AR operand is a convert-wrapped bf16 dot.)"""
    comps = parse_computations(hlo)
    # per-computation name->type map (params + instrs)
    types_per_comp: Dict[str, Dict[str, str]] = {}
    producers_per_comp: Dict[str, Dict[str, Instr]] = {}
    for cname, instrs in comps.items():
        t = {}
        prod = {}
        for ins in instrs:
            t[ins.name] = ins.type_str
            prod[ins.name] = ins
        types_per_comp[cname] = t
        producers_per_comp[cname] = prod

    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps))

    res = CostResult()
    coll = defaultdict(lambda: {"count": 0.0, "bytes_operand": 0.0,
                                "bytes_wire": 0.0})

    def walk(cname: str, mult: float, seen: Tuple[str, ...],
             count_bytes: bool):
        if cname not in comps or cname in seen:
            return
        types = types_per_comp[cname]
        producers = producers_per_comp[cname]
        for ins in comps[cname]:
            c = _collective_cost(ins, types, producers)
            if c is not None:
                base, ob, wire = c
                if bf16_collectives and "f32[" in ins.type_str:
                    ob *= 0.5
                    wire *= 0.5
                coll[base]["count"] += mult
                coll[base]["bytes_operand"] += ob * mult
                coll[base]["bytes_wire"] += wire * mult
            mxu, vpu = _instr_cost(ins, types)
            res.mxu_flops += mxu * mult
            res.vpu_flops += vpu * mult
            if count_bytes:
                res.hbm_bytes += _instr_bytes(ins, types) * mult
            # recurse into callees
            callees = _CALLEE_RE.findall(ins.rest)
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                callees += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
            child_mult = mult
            if ins.opcode == "while":
                mt = _TRIP_RE.search(ins.rest)
                child_mult = mult * (int(mt.group(1)) if mt else 1)
            # bytes: count only at top level of while/call/cond bodies —
            # a fusion's internals are VMEM-resident (already charged at
            # the fusion instruction itself)
            child_bytes = count_bytes and ins.opcode in (
                "while", "call", "conditional")
            for callee in callees:
                walk(callee, child_mult, seen + (cname,), child_bytes)

    walk(entry, 1.0, (), True)
    res.coll = {k: dict(v) for k, v in coll.items()}
    return res
