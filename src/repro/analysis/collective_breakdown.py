"""Per-shape collective attribution: which tensors' collectives dominate a
compiled module. The hillclimb's profiler (DESIGN.md: 'your profile is
lowered.as_text() + cost_analysis')."""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

from repro.analysis.hlocost import (_TRIP_RE, _CALLEE_RE, _collective_cost,
                                     parse_computations)


def collective_breakdown(hlo: str, top: int = 15) -> List[Dict]:
    comps = parse_computations(hlo)
    types_per_comp = {c: {i.name: i.type_str for i in instrs}
                      for c, instrs in comps.items()}
    producers_per_comp = {c: {i.name: i for i in instrs}
                          for c, instrs in comps.items()}
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    entry = m.group(1) if m else next(iter(comps))

    agg = defaultdict(lambda: {"count": 0.0, "wire": 0.0, "operand": 0.0})

    def walk(cname, mult, seen):
        if cname not in comps or cname in seen:
            return
        types = types_per_comp[cname]
        producers = producers_per_comp[cname]
        for ins in comps[cname]:
            c = _collective_cost(ins, types, producers)
            if c is not None:
                op, ob, wire = c
                key = (op, ins.type_str.strip()[:64])
                agg[key]["count"] += mult
                agg[key]["wire"] += wire * mult
                agg[key]["operand"] += ob * mult
            callees = _CALLEE_RE.findall(ins.rest)
            child = mult
            if ins.opcode == "while":
                mt = _TRIP_RE.search(ins.rest)
                child = mult * (int(mt.group(1)) if mt else 1)
            for cal in callees:
                walk(cal, child, seen + (cname,))

    walk(entry, 1.0, ())
    rows = [{"op": k[0], "shape": k[1], **v} for k, v in agg.items()]
    rows.sort(key=lambda r: -r["wire"])
    return rows[:top]
