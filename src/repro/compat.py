"""Version/environment-robust dependency shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to the
top-level ``jax`` namespace; installed JAX versions straddle the move.
Import it from here everywhere (DESIGN.md §2) so the repo runs on both.

``zstd`` is optional: segment files fall back to stdlib zlib with the
same two-method Compressor/Decompressor surface. The container byte
format differs between the two backends, but segment files are
machine-local (crash recovery, not interchange), so self-consistency is
all that is required.
"""
from __future__ import annotations

import zlib

import jax

try:
    shard_map = jax.shard_map           # jax >= 0.6
except AttributeError:                  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        """Experimental-era shard_map spelled with the modern signature
        (``check_vma`` was named ``check_rep`` before the graduation)."""
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)


def cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a one-element list of dicts on
    older JAX and a plain dict on newer; normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


class _ZlibCompressor:
    def __init__(self, level: int = 3):
        self.level = level

    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, self.level)


class _ZlibDecompressor:
    def decompress(self, data: bytes) -> bytes:
        return zlib.decompress(data)


class _ZlibZstdModule:
    """Minimal stand-in for the ``zstandard`` module."""
    ZstdCompressor = _ZlibCompressor
    ZstdDecompressor = _ZlibDecompressor


try:  # pragma: no cover - environment dependent
    import zstandard as zstd  # noqa: F401
except ImportError:  # pragma: no cover - environment dependent
    zstd = _ZlibZstdModule()

__all__ = ["shard_map", "zstd", "cost_analysis"]
