"""GPipe-style pipeline parallelism over a mesh axis via shard_map +
collective_permute.

Layers are grouped into S = mesh.shape[axis] stages (each device holds its
stage's parameter slice); M microbatches flow through a T = M + S - 1 tick
schedule; stage boundaries move activations with ``ppermute`` (one hop per
tick, fully overlappable with the next tick's compute on TPU). Backward is
ordinary autodiff through the schedule (ppermute transposes to the reverse
permutation), i.e. GPipe's synchronous fill-drain pipeline with re-
materialized stages.

This is a feature module for very deep models (the fixed production mesh
uses DP x TP by default); tests exercise it on a host-device mesh and check
exact equivalence with the sequential stack, including gradients.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stacked_params, x: jax.Array,
                   mesh, *, axis: str = "model", n_micro: int = None):
    """Run ``y = stage_fn(params_s, y)`` for s = 0..S-1 over the pipeline.

    stacked_params: pytree with leading dim S (one slice per stage).
    x: (B, ...) global batch; split into n_micro microbatches (default S).
    Returns y with the same shape as x.
    """
    S = mesh.shape[axis]
    M = n_micro or S
    B = x.shape[0]
    assert B % M == 0, (B, M)

    p_spec = jax.tree.map(lambda _: P(axis), stacked_params)
    x_spec = P()          # replicated in; every stage sees all microbatches
    out_spec = P()

    def fn(params_local, xl):
        # params_local: leading dim 1 (this stage's slice)
        params_s = jax.tree.map(lambda a: a[0], params_local)
        s = jax.lax.axis_index(axis)
        micro = xl.reshape((M, B // M) + xl.shape[1:])
        buf = jnp.zeros_like(micro[0])          # incoming activation
        outs = jnp.zeros_like(micro)
        fwd = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if in range); others take buf
            mb_idx = jnp.clip(t - s, 0, M - 1)
            inject = jax.lax.dynamic_index_in_dim(micro, mb_idx, 0,
                                                  keepdims=False)
            inp = jnp.where(s == 0, inject, buf)
            active = (t - s >= 0) & (t - s < M)
            y = stage_fn(params_s, inp)
            y = jnp.where(active, y, buf)
            # last stage banks its result at position t-(S-1)
            bank = (s == S - 1) & active
            pos = jnp.clip(t - (S - 1), 0, M - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, pos, 0, keepdims=False)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(bank, y, cur), pos, 0)
            buf_next = jax.lax.ppermute(y, axis, fwd)
            return buf_next, outs

        buf, outs = jax.lax.fori_loop(0, M + S - 1, tick, (buf, outs))
        # only stage S-1 banked non-zero outputs; psum broadcasts them
        # (other stages contribute exact zeros)
        outs = jax.lax.psum(outs, axis)
        return outs.reshape(xl.shape)

    return shard_map(fn, mesh=mesh, in_specs=(p_spec, x_spec),
                     out_specs=out_spec, check_vma=False)(stacked_params, x)


def sequential_apply(stage_fn: Callable, stacked_params, x: jax.Array):
    """Reference: the same stack applied sequentially."""
    def body(y, p):
        return stage_fn(p, y), None
    y, _ = jax.lax.scan(body, x, stacked_params)
    return y
