"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Every parameter carries logical axis names from its PD descriptor; the
rules below translate them to mesh axes. An axis is only sharded when the
dimension divides the mesh-axis size — otherwise it is replicated (this is
why e.g. qwen2's 12 attention heads replicate over a 16-way model axis; the
roofline table shows the imbalance honestly).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import PD, is_pd

# logical axis -> candidate mesh axis (model/tensor parallel dimension)
_MODEL_AXES = {
    "vocab": "model",
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "ssm_inner": "model",
    "ssm_heads": "model",
    "lru": "model",
    "lru_heads": "model",
    "experts": None,       # resolved per-config (ep vs tp)
    "experts_r": None,     # router output dim: small, replicate
    "expert_mlp": None,    # resolved per-config
}


def rules_for(cfg: ModelConfig) -> Dict[str, Optional[str]]:
    rules = dict(_MODEL_AXES)
    if cfg.moe is not None:
        if cfg.moe.sharding == "ep":
            rules["experts"] = "model"
            rules["expert_mlp"] = None
        else:
            rules["experts"] = None
            rules["expert_mlp"] = "model"
    return rules


def spec_for(pd: PD, cfg: ModelConfig, mesh: Mesh) -> P:
    """Head-parallel when heads divide the model axis; otherwise fall back
    to ROW-PARALLEL (shard the embed dim). Replicating an attention
    projection because 56 (or 12, or 10) heads don't divide 16 costs 16x
    the memory for the same compute — attention intermediates duplicate
    across the model axis either way (§Perf iteration 9)."""
    rules = rules_for(cfg)
    axes = []
    wanted_model = False
    for dim, name in zip(pd.shape, pd.axes):
        mesh_axis = rules.get(name) if name else None
        if mesh_axis is not None and mesh_axis in mesh.axis_names:
            if dim % mesh.shape[mesh_axis] == 0:
                axes.append(mesh_axis)
                continue
            # fallback only for head-type axes (a non-divisible vocab must
            # stay replicated: feature-sharding an embedding table breaks
            # the gather/one-hot lowering)
            if mesh_axis == "model" and name in ("heads", "kv_heads",
                                                 "lru_heads"):
                wanted_model = True
        axes.append(None)
    if wanted_model and "model" not in axes and "model" in mesh.axis_names:
        n = mesh.shape["model"]
        for i, (dim, name) in enumerate(zip(pd.shape, pd.axes)):
            # only when the saving is material (small-d models replicate
            # cheaply, and feature-sharding tiny dims trips XLA:CPU SPMD)
            if name == "embed" and axes[i] is None and dim % n == 0 \
                    and dim >= 1024:
                axes[i] = "model"
                break
    return P(*axes)


def param_specs(desc: Dict, cfg: ModelConfig, mesh: Mesh) -> Dict:
    def one(pd: PD) -> P:
        base = spec_for(pd, cfg, mesh)
        if cfg.fsdp:
            # ZeRO-3: additionally shard the largest free dim over "data";
            # XLA inserts the per-layer all-gather (FSDP semantics).
            base = zero1_spec(pd.shape, base, mesh)
        return base
    return jax.tree.map(one, desc, is_leaf=is_pd)


def param_shardings(desc: Dict, cfg: ModelConfig, mesh: Mesh) -> Dict:
    specs = param_specs(desc, cfg, mesh)  # fsdp-aware
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(mesh: Mesh, extra_dims: int = 1, leading: int = 0) -> P:
    """Spec for (B, ...) batch arrays: batch over DP axes, rest replicated."""
    return P(*([None] * leading), dp_axes(mesh), *([None] * extra_dims))


def zero1_spec(pd_shape: Tuple[int, ...], base: P, mesh: Mesh) -> P:
    """Extend a param spec with DP-axis sharding on the largest free dim
    (ZeRO-1/3 state sharding). On the multi-pod mesh the shard extends over
    ("pod","data") — 32-way — so per-chip param/optimizer state halves when
    a job scales out."""
    flat = []
    for entry in tuple(base):
        if isinstance(entry, (tuple, list)):
            flat.extend(entry)
        elif entry is not None:
            flat.append(entry)
    if "data" in flat:
        return base
    dp = dp_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape.get(a, 1)
    axes = list(base) + [None] * (len(pd_shape) - len(base))
    best, best_dim = -1, -1
    for i, (dim, ax) in enumerate(zip(pd_shape, axes)):
        if ax is None and dim % n_dp == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        axes[best] = dp if len(dp) > 1 else dp[0]
    return P(*axes)


def cache_specs(cfg: ModelConfig, cache: Dict, mesh: Mesh) -> Dict:
    """Shardings for decode caches.

    KV caches shard batch over DP axes and the *sequence* dim over "model"
    (flash-decoding style sequence parallelism) because most assigned archs
    have kv_heads that do not divide the model axis. SSM/LRU states shard
    heads/width over "model".
    """
    dp = dp_axes(mesh)
    n_model = mesh.shape["model"]
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]

    def spec(path: str, x) -> P:
        shape = x.shape
        if path == "pos":
            return P()
        b_ok = len(shape) > 1 and shape[1] % n_dp == 0
        if path in ("k", "v", "cross_k", "cross_v"):
            # (L, B, S, Hkv, hd)
            s_ok = shape[2] % n_model == 0
            return P(None, dp if b_ok else None, "model" if s_ok else None,
                     None, None)
        if path in ("g_k", "g_v"):
            # (G, B, W, Hkv, hd) — window cache: batch-only sharding
            return P(None, dp if b_ok else None, None, None, None)
        if path == "ssd":
            # (L, B, H, P, N)
            h_ok = shape[2] % n_model == 0
            return P(None, dp if b_ok else None, "model" if h_ok else None,
                     None, None)
        if path == "conv":
            # (L, B, K-1, C)
            c_ok = shape[3] % n_model == 0
            return P(None, dp if b_ok else None, None,
                     "model" if c_ok else None)
        if path in ("g_conv", "t_conv", "g_lru", "t_lru"):
            # (..., B, [K-1,] C/W): shard the trailing channel dim over model
            c_ok = shape[-1] % n_model == 0
            return P(*([None] * (len(shape) - 1)), "model" if c_ok else None)
        return P()

    return {k: NamedSharding(mesh, spec(k, v)) for k, v in cache.items()}
