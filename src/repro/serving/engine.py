"""Serving: prefill + single-token decode step factories."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig


def make_serve_step(cfg: ModelConfig, mesh=None):
    """serve_step(params, cache, batch) -> (logits, new_cache).

    ``batch`` carries the one new token (or embed) + positions; the KV/SSM
    cache holds ``seq_len`` of context, matching the decode_* input shapes.
    """
    def serve_step(params, cache, batch):
        compute_params = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.dtype)) if p.dtype == jnp.float32 else p,
            params)
        return models.decode_step(cfg, compute_params, cache, batch, mesh)
    return serve_step


def make_prefill_step(cfg: ModelConfig, mesh=None):
    """prefill(params, batch) -> (last_logits, cache)."""
    def prefill(params, batch):
        compute_params = jax.tree.map(
            lambda p: p.astype(jnp.dtype(cfg.dtype)) if p.dtype == jnp.float32 else p,
            params)
        hidden, _, cache = models.forward(cfg, compute_params, batch, mesh,
                                          emit_cache=cfg.family in
                                          ("dense", "vlm", "moe"))
        last = hidden[:, -1:, :]
        logits = models.logits_fn(cfg, compute_params, last, mesh)
        return logits, cache
    return prefill


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
