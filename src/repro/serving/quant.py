"""Weight-only int8 quantization for serving (hillclimb: halves the
parameter-read memory term of decode cells).

Per-output-channel symmetric scales (last dim); dequant happens at load
into the matmul — on TPU the int8->bf16 convert fuses into the dot's
operand read, so HBM traffic is the int8 bytes. Embeddings / norms /
vectors stay bf16 (quality), as do conv kernels.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.layers import PD, is_pd


def _quantizable(pd: PD) -> bool:
    return len(pd.shape) >= 2 and pd.init == "normal" and \
        pd.axes[0] != "vocab"  # keep embedding bf16 (tied logits quality)


def quantize_params(params: Dict, desc: Dict) -> Dict:
    """params tree -> tree with {"q": int8, "s": bf16-scale} leaves for
    quantizable weights."""
    def q(p, pd):
        if not _quantizable(pd):
            return p.astype(jnp.bfloat16) if p.dtype == jnp.float32 else p
        a = jnp.max(jnp.abs(p.astype(jnp.float32)), axis=tuple(
            range(p.ndim - 1)), keepdims=False)
        s = jnp.maximum(a, 1e-8) / 127.0
        qv = jnp.clip(jnp.round(p.astype(jnp.float32) / s), -127, 127)
        return {"q": qv.astype(jnp.int8), "s": s.astype(jnp.bfloat16)}
    return jax.tree.map(q, params, desc, is_leaf=lambda x: is_pd(x))


def dequantize_params(qparams: Dict, dtype=jnp.bfloat16) -> Dict:
    def dq(leaf):
        if isinstance(leaf, dict) and set(leaf) == {"q", "s"}:
            return (leaf["q"].astype(jnp.float32) *
                    leaf["s"].astype(jnp.float32)).astype(dtype)
        return leaf
    return jax.tree.map(dq, qparams,
                        is_leaf=lambda x: isinstance(x, dict)
                        and set(x) == {"q", "s"})


def abstract_qparams(cfg, desc: Dict) -> Dict:
    """ShapeDtypeStructs for the quantized tree (dry-run)."""
    def one(pd: PD):
        if not _quantizable(pd):
            return jax.ShapeDtypeStruct(pd.shape, jnp.bfloat16)
        return {"q": jax.ShapeDtypeStruct(pd.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct((pd.shape[-1],), jnp.bfloat16)}
    return jax.tree.map(one, desc, is_leaf=is_pd)


def qparam_shardings(cfg, desc: Dict, mesh) -> Dict:
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.sharding import spec_for

    def one(pd: PD):
        spec = spec_for(pd, cfg, mesh)
        if not _quantizable(pd):
            return NamedSharding(mesh, spec)
        axes = list(spec) + [None] * (len(pd.shape) - len(spec))
        return {"q": NamedSharding(mesh, spec),
                "s": NamedSharding(mesh, P(axes[-1]))}
    return jax.tree.map(one, desc, is_leaf=is_pd)


def make_quantized_serve_step(cfg, mesh=None):
    from repro import models

    def serve_step(qparams, cache, batch):
        params = dequantize_params(qparams, jnp.dtype(cfg.dtype))
        return models.decode_step(cfg, params, cache, batch, mesh)
    return serve_step
