"""Dry-run cells for the PAPER'S OWN pipelines on the production meshes.

Complements dryrun.py's 40 architecture cells with:

  icicle-counting   one counting-pipeline wave: 1M rows/device, 64Ki
                    principals sharded over "model", psum-merged counts
  icicle-aggregate  one aggregate-pipeline wave: grouped DDSketch update
                    (64Ki principals x 4 attrs x 2048 buckets) + psum merge
  icicle-monitor    one monitor tick per MDT: 8192-event reduction +
                    hierarchy pointer-jumping over 1M-fid state, one MDT
                    per device (the paper's monitor-per-MDT scaling rule)

Note: these lower the pure-jnp (scatter) formulation — the Pallas kernels
target real TPUs and are validated in interpret mode; XLA:CPU cannot
compile Mosaic kernels. Collective structure and memory are identical.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from repro.compat import shard_map  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import events as ev  # noqa: E402
from repro.core import hierarchy as hi  # noqa: E402
from repro.core import reduction  # noqa: E402
from repro.core import snapshot as snap  # noqa: E402
from repro.core.sketches.ddsketch import DDSketchConfig  # noqa: E402
from repro.launch.dryrun import analyze_compiled  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

ROWS_PER_DEVICE = 1 << 20      # counting
AGG_ROWS_PER_DEVICE = 1 << 19  # aggregate (sketch state is large)
N_PRINCIPALS = 1 << 16
EVENTS_PER_MDT = 8192
MAX_FIDS = 1 << 20


def _pipeline_cfg() -> snap.PipelineConfig:
    return snap.PipelineConfig(
        n_users=N_PRINCIPALS // 2, n_groups=N_PRINCIPALS // 4,
        n_dirs=N_PRINCIPALS // 4, sketch=DDSketchConfig(n_buckets=2048))


def _row_specs(n_rows: int) -> Dict:
    sd = jax.ShapeDtypeStruct
    return {
        "uid_slot": sd((n_rows,), jnp.int32),
        "gid_slot": sd((n_rows,), jnp.int32),
        "dir_slots": sd((n_rows, 3), jnp.int32),
        "shard_id": sd((n_rows,), jnp.int32),
        "size": sd((n_rows,), jnp.float32),
        "atime": sd((n_rows,), jnp.float32),
        "ctime": sd((n_rows,), jnp.float32),
        "mtime": sd((n_rows,), jnp.float32),
        "uid": sd((n_rows,), jnp.int32),
        "gid": sd((n_rows,), jnp.int32),
        "mode": sd((n_rows,), jnp.int32),
        "type": sd((n_rows,), jnp.int32),
        "path_hash": sd((n_rows,), jnp.uint32),
    }


def lower_counting(mesh):
    cfg = _pipeline_cfg()
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_rows = ROWS_PER_DEVICE * n_dp
    step = snap.make_counting_step(cfg, mesh, dp_axes=dp)
    rows = _row_specs(n_rows)
    valid = jax.ShapeDtypeStruct((n_rows,), jnp.bool_)
    in_sh = ({k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
              for k, v in rows.items()},
             NamedSharding(mesh, P(dp)))
    return jax.jit(step, in_shardings=in_sh).lower(rows, valid)


def lower_aggregate(mesh):
    cfg = _pipeline_cfg()
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_rows = AGG_ROWS_PER_DEVICE * n_dp
    step = snap.make_aggregate_step(cfg, mesh, dp_axes=dp)
    rows = _row_specs(n_rows)
    valid = jax.ShapeDtypeStruct((n_rows,), jnp.bool_)
    in_sh = ({k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
              for k, v in rows.items()},
             NamedSharding(mesh, P(dp)))
    return jax.jit(step, in_shardings=in_sh).lower(rows, valid)


def lower_monitor(mesh):
    """One monitor tick on every device: vmapped reduce+apply over the MDT
    axis, one MDT per chip (paper §IV-B4)."""
    all_axes = tuple(mesh.axis_names)
    n_mdt = mesh.devices.size

    def tick(state, batch, valid):
        def one(state, batch, valid):
            red = reduction.reduce_batch(batch, valid)
            return reduction.apply_batch(state, red, max_depth=64)
        return jax.vmap(one)(state, batch, valid)

    sd = jax.ShapeDtypeStruct
    state = {
        "parent": sd((n_mdt, MAX_FIDS), jnp.int32),
        "name_hash": sd((n_mdt, MAX_FIDS), jnp.uint32),
        "exists": sd((n_mdt, MAX_FIDS), jnp.bool_),
        "is_dir": sd((n_mdt, MAX_FIDS), jnp.bool_),
        "path_hash": sd((n_mdt, MAX_FIDS), jnp.uint32),
    }
    batch = {k: sd((n_mdt, EVENTS_PER_MDT), v.dtype)
             for k, v in ev.empty_batch(1).items()}
    valid = sd((n_mdt, EVENTS_PER_MDT), jnp.bool_)
    mdt_sharding = NamedSharding(mesh, P(all_axes))
    in_sh = (jax.tree.map(lambda _: mdt_sharding, state),
             jax.tree.map(lambda _: mdt_sharding, batch),
             mdt_sharding)
    return jax.jit(tick, in_shardings=in_sh, donate_argnums=(0,)
                   ).lower(state, batch, valid)


CELLS = {
    "icicle-counting": lower_counting,
    "icicle-aggregate": lower_aggregate,
    "icicle-monitor": lower_monitor,
}


def run_cell(name: str, multi_pod: bool) -> Dict:
    base = {"arch": name, "shape": "pipeline_wave",
            "mesh": "2x16x16" if multi_pod else "16x16", "tag": "icicle"}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        lowered = CELLS[name](mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = analyze_compiled(lowered, compiled, None, None, mesh)
        rec.update(base)
        rec.update({"status": "ok", "lower_s": round(t_lower, 2),
                    "compile_s": round(t_compile, 2)})
        return rec
    except Exception as e:
        return {**base, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None)
    ap.add_argument("--cell", default=None)
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(CELLS)
    for name in cells:
        for mp in (False, True):
            rec = run_cell(name, mp)
            line = json.dumps({k: v for k, v in rec.items()
                               if k != "traceback"})
            print(line[:400])
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")
            jax.clear_caches()


if __name__ == "__main__":
    main()
