"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be imported/run fresh: the XLA_FLAGS below must be set before jax
initializes devices (jax locks the device count on first init).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --sweep --out results/dryrun.jsonl
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from collections import defaultdict  # noqa: E402
from typing import Dict, Optional, Tuple  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import models  # noqa: E402
from repro.configs import SHAPES, get_config, shape_applicable  # noqa: E402
from repro.configs.all_archs import ARCH_IDS  # noqa: E402
from repro.data import specs as dspecs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.serving.engine import make_prefill_step, make_serve_step  # noqa: E402
from repro.training.optimizer import AdamWConfig, init_opt_state  # noqa: E402
from repro.training.steps import (batch_shardings, make_train_shardings,  # noqa: E402
                                  make_train_step)

# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_cell(arch: str, shape_name: str, mesh, step_override=None):
    """Returns jax Lowered for the cell's step function."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind in ("prefill", "decode"):
        # Serving deploys bf16 weights (f32 masters are a training-only
        # artifact) and NEVER fsdp-sharded params: per-layer all-gathers
        # per decoded token would dominate the step (§Perf iterations 1+6).
        cfg = cfg.replace(param_dtype="bfloat16", fsdp=False)
    if step_override is not None:
        cfg = step_override(cfg)
    desc = models.param_desc(cfg)
    aparams = models.abstract_params(cfg)

    if shape.kind == "train":
        psh, osh, bsh = make_train_shardings(cfg, mesh)
        mdt = "bfloat16" if cfg.param_dtype == "bfloat16" else "float32"
        aopt = jax.eval_shape(lambda p: init_opt_state(p, mdt), aparams)
        step = make_train_step(cfg, AdamWConfig(moment_dtype=mdt), mesh)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        binput = dspecs.train_input_specs(cfg, shape)
        return jitted.lower(aparams, aopt, binput), cfg

    psh = shd.param_shardings(desc, cfg, mesh)
    if shape.kind == "prefill":
        step = make_prefill_step(cfg, mesh)
        bsh = batch_shardings(cfg, mesh)
        bsh.pop("labels", None)
        binput = dspecs.train_input_specs(cfg, shape)
        binput.pop("labels", None)
        jitted = jax.jit(step, in_shardings=(psh, bsh))
        return jitted.lower(aparams, binput), cfg

    # decode
    step = make_serve_step(cfg, mesh)
    batch, cache = dspecs.decode_input_specs(cfg, shape)
    csh = shd.cache_specs(cfg, cache, mesh)
    dp = shd.dp_axes(mesh)
    bsh = {}
    for k in batch:
        if k == "positions" and cfg.mrope_input:
            bsh[k] = NamedSharding(mesh, P(None, dp, None))
        elif k == "embeds":
            bsh[k] = NamedSharding(mesh, P(dp, None, None))
        else:
            bsh[k] = NamedSharding(mesh, P(dp, None))
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    if shape.global_batch % n_dp != 0:  # e.g. long_500k batch=1
        bsh = {k: NamedSharding(mesh, P()) for k in batch}
    jitted = jax.jit(step, in_shardings=(psh, csh, bsh),
                     out_shardings=None, donate_argnums=(1,))
    return jitted.lower(aparams, cache, batch), cfg


def analyze_compiled(lowered, compiled, cfg, shape, mesh) -> Dict:
    from repro.analysis.hlocost import analyze_hlo

    from repro.compat import cost_analysis
    ca = cost_analysis(compiled)
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    hlo = compiled.as_text()
    # trip-count-aware analysis; bf16-collective correction only applies to
    # bf16-compute model programs (icicle pipelines use genuine f32 sums)
    bf16 = bool(cfg is not None and cfg.dtype == "bfloat16")
    cost = analyze_hlo(hlo, bf16_collectives=bf16)
    n_chips = mesh.devices.size
    record = {
        # per-device numbers; xla_* are the raw (scan-body-once) versions
        "mxu_flops_per_device": cost.mxu_flops,
        "vpu_flops_per_device": cost.vpu_flops,
        "xla_flops_per_device": float(ca.get("flops", 0.0)),
        "xla_bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "memory": mem,
        "collectives": cost.coll,
        "coll_operand_bytes": cost.coll_operand_bytes,
        "coll_wire_bytes": cost.coll_wire_bytes,
        "n_chips": int(n_chips),
        "hlo_bytes": len(hlo),
    }
    return record


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             step_override=None, tag: str = "") -> Dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    base = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag}
    if not ok:
        return {**base, "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, cfg2 = lower_cell(arch, shape_name, mesh, step_override)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        rec = analyze_compiled(lowered, compiled, cfg2, shape, mesh)
        rec.update(base)
        rec.update({
            "status": "ok",
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "param_count": cfg2.param_count(),
            "active_param_count": cfg2.active_param_count(),
        })
        return rec
    except Exception as e:
        return {**base, "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells already present in --out")
    args = ap.parse_args()

    done = set()
    if args.out and args.skip_done and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass

    def emit(rec):
        line = json.dumps(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(line + "\n")
        slim = {k: v for k, v in rec.items() if k not in ("traceback",)}
        print(json.dumps(slim)[:400])

    if args.sweep:
        cells = []
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
        for arch, shape, mp in cells:
            key = (arch, shape, "2x16x16" if mp else "16x16")
            if key in done:
                print("skip done:", key)
                continue
            emit(run_cell(arch, shape, mp))
            jax.clear_caches()  # bound compile-cache memory across 80 cells
        return

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    emit(rec)
    if rec["status"] == "ok":
        print(f"memory_analysis: {rec['memory']}")
        print(f"cost: mxu/dev={rec['mxu_flops_per_device']:.3e} "
              f"vpu/dev={rec['vpu_flops_per_device']:.3e} "
              f"coll_wire={rec['coll_wire_bytes']:.3e}")
        print(f"collectives: {json.dumps(rec['collectives'])[:500]}")


if __name__ == "__main__":
    main()
