"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips with a leading "pod" axis that carries
pure data parallelism across the pod-interconnect.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; older versions default to
    # Auto axes and reject the kwarg.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic restarts, tests, hillclimb variants)."""
    return _make_mesh(shape, axes)


def make_smoke_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh over however many (host) devices are available."""
    return make_mesh((n_data, n_model), ("data", "model"))


# Hardware constants for the roofline model: TPU v5e.
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per chip, one direction)
HBM_PER_CHIP = 16 * 2**30     # 16 GiB
