"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20

Features exercised end-to-end: config system -> model zoo -> data pipeline
(hedged reads) -> jitted train step (remat, microbatching, zero1/fsdp
shardings when a mesh is given) -> checkpoint/restart (crash-safe, elastic)
-> Icicle monitoring of the checkpoint directory (the paper's system
watching its own training cluster).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import events as ev
from repro.core.monitor import Monitor, MonitorConfig
from repro.core.metadata import path_hash
from repro.data.pipeline import BatchIterator, DataConfig
from repro.data.specs import reduced_config
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.steps import make_train_step


def train(arch: str, steps: int, *, reduced: bool = True,
          global_batch: int = 4, seq_len: int = 128,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
          resume: bool = True, lr: float = 1e-3, log_every: int = 10,
          monitor: bool = True, seed: int = 0,
          stop_after: Optional[int] = None):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    assert not cfg.embeds_input or cfg.family == "audio", \
        "train driver feeds token batches; use examples/ for vlm stubs"

    # Icicle watches the checkpoint directory (creates/closes per shard).
    ckpt_stream = ev.EventStream(start_fid=1)
    mon = Monitor(MonitorConfig(max_fids=1 << 12, batch_size=256)) \
        if monitor and ckpt_dir else None

    def event_sink(kind: str, path: str):
        fid = (path_hash(path) % ((1 << 12) - 1)) + 1
        et = ev.E_CREAT if kind == "CREAT" else ev.E_CLOSE
        ckpt_stream.emit(et, fid, 0, name_hash=path_hash(path))

    params = models.init_params(cfg, jax.random.PRNGKey(seed))
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=max(2, steps // 20),
                          total_steps=steps)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    data = BatchIterator(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=seq_len,
                                    global_batch=global_batch, seed=seed))
    start_step = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep_n=3, event_sink=event_sink)
        if resume and mgr.latest() is not None:
            tree = {"params": params, "opt": opt_state}
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
            restored, manifest = mgr.restore(abstract)
            params, opt_state = restored["params"], restored["opt"]
            start_step = manifest["step"]
            data.seek(start_step)
            print(f"[train] resumed from step {start_step}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        batch = next(data)
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.family == "audio":  # enc-dec: frames stub from tokens
            emb = np.random.default_rng(step).normal(
                0, 0.02, (global_batch, seq_len, cfg.d_model))
            jb["embeds"] = jnp.asarray(emb, jnp.dtype(cfg.dtype))
        params, opt_state, m = step_fn(params, opt_state, jb)
        losses.append(float(m["loss"]))
        if log_every and (step + 1) % log_every == 0:
            dt = time.perf_counter() - t0
            print(f"[train] step {step + 1} loss={losses[-1]:.4f} "
                  f"gnorm={float(m['grad_norm']):.3f} "
                  f"({(step + 1 - start_step) / dt:.2f} it/s)")
        if mgr and ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
            if mon is not None:
                while len(ckpt_stream):
                    mon.process(ckpt_stream.take(256))
        if stop_after is not None and step + 1 >= stop_after:
            break  # simulated preemption/crash (tests)

    if mon is not None:
        print(f"[icicle] checkpoint-dir events processed: "
              f"{mon.metrics['events_in']}, live objects: "
              f"{int(jnp.sum(mon.state['exists']))}")
    return {"losses": losses, "params": params, "opt": opt_state,
            "final_loss": losses[-1] if losses else float("nan")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, args.steps, reduced=args.reduced,
                global_batch=args.batch, seq_len=args.seq_len,
                ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                lr=args.lr, seed=args.seed)
    print(f"[train] done: final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
