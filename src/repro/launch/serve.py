"""Serving driver: batched prefill + decode on a (reduced) model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --batch 4 --new-tokens 32 [--int8]

The full-size serving path is exercised by the decode_32k / long_500k
dry-run cells (launch/dryrun.py); this driver runs end-to-end on CPU with
reduced configs and reports tokens/s.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs import get_config
from repro.data.specs import reduced_config
from repro.serving.engine import greedy_sample, make_serve_step
from repro.serving.quant import dequantize_params, quantize_params


def serve(arch: str, *, batch: int = 4, prompt_len: int = 16,
          new_tokens: int = 32, int8: bool = False, reduced: bool = True,
          seed: int = 0):
    cfg = get_config(arch)
    if reduced:
        cfg = reduced_config(cfg)
    assert not cfg.embeds_input or cfg.family == "audio", \
        "vlm frontend is stubbed; use dry-run cells for qwen2-vl serving"
    params = models.init_params(cfg, jax.random.PRNGKey(seed))
    if int8:
        qp = quantize_params(params, models.param_desc(cfg))
        params = dequantize_params(qp, jnp.dtype(cfg.dtype))

    rng = np.random.default_rng(seed)
    max_len = prompt_len + new_tokens
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    cache = models.init_cache(cfg, batch, max_len)
    step = jax.jit(make_serve_step(cfg))

    logits = None
    for t in range(prompt_len):
        b = {"tokens": jnp.asarray(prompts[:, t:t + 1], jnp.int32),
             "positions": jnp.full((batch, 1), t, jnp.int32)}
        logits, cache = step(params, cache, b)
    tok = greedy_sample(logits)
    t0 = time.perf_counter()
    out = [tok]
    for t in range(prompt_len, max_len - 1):
        b = {"tokens": tok[:, None],
             "positions": jnp.full((batch, 1), t, jnp.int32)}
        logits, cache = step(params, cache, b)
        tok = greedy_sample(logits)
        out.append(tok)
    dt = time.perf_counter() - t0
    n = len(out) * batch
    return {"tokens_per_s": n / dt, "generated": len(out)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--int8", action="store_true")
    args = ap.parse_args()
    r = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
              new_tokens=args.new_tokens, int8=args.int8)
    print(f"[serve] {r['generated']} steps, {r['tokens_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
