"""Sharded, versioned, elastic checkpointing.

Layout (one directory per step):

    ckpt_dir/step_000123/
        leaf files:  <flat-key>.<chunk>.zst   (msgpack+zstd array chunks)
        MANIFEST.json                          (written LAST = commit marker)

- **Atomicity / crash safety**: a step directory without MANIFEST.json is
  incomplete and ignored by discovery; restart resumes from the newest
  complete step (mirrors the paper's snapshot version IDs — stale or
  partial versions are invalidated on ingest).
- **Elasticity**: leaves store the GLOBAL array plus its logical chunking;
  restore re-shards onto any mesh via ``jax.device_put`` with the target
  sharding, so a job checkpointed on (16,16) restarts on (8,16) or
  (2,16,16) unchanged.
- **Chunked leaf files** emulate per-host shard writes (chunk = leading-dim
  slice): on a real pod each host writes its own chunks in parallel.
- **Async**: ``save_async`` hands the host copy to a worker thread.
- **Icicle integration**: every file write emits CREAT/CLOSE events to an
  optional monitor stream — the paper's indexing system watches its own
  training cluster's checkpoints (checkpoint GC queries the primary index).
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
from repro.compat import zstd


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}.{i}", v)
        else:
            flat[prefix] = node
    walk("", tree)
    return flat


def _unflatten_into(abstract, flat: Dict[str, Any]):
    def walk(prefix, node):
        if isinstance(node, dict):
            return {k: walk(f"{prefix}.{k}" if prefix else str(k), node[k])
                    for k in sorted(node)}
        if isinstance(node, (list, tuple)):
            vals = [walk(f"{prefix}.{i}", v) for i, v in enumerate(node)]
            return type(node)(vals)
        return flat[prefix]
    return walk("", abstract)


_SAFE = re.compile(r"[^A-Za-z0-9_.\-]")


def _fname(key: str) -> str:
    return _SAFE.sub("_", key)


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    chunks: int = 4, event_sink: Optional[Callable] = None,
                    extra_meta: Optional[Dict] = None) -> str:
    """Blocking save. Returns the step directory path."""
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp_dir = step_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    flat = _flatten(tree)
    comp = zstd.ZstdCompressor(level=3)
    manifest = {"step": step, "leaves": {}, "time": time.time(),
                "extra": extra_meta or {}}
    for key, arr in flat.items():
        a = np.asarray(arr)
        n_chunks = min(chunks, a.shape[0]) if a.ndim >= 1 and a.shape[0] >= chunks else 1
        splits = np.array_split(a, n_chunks, axis=0) if a.ndim >= 1 else [a]
        files = []
        for ci, chunk in enumerate(splits):
            fn = f"{_fname(key)}.{ci}.zst"
            payload = msgpack.packb({
                "shape": list(chunk.shape), "dtype": str(chunk.dtype),
                "data": chunk.tobytes(),
            }, use_bin_type=True)
            with open(os.path.join(tmp_dir, fn), "wb") as f:
                f.write(comp.compress(payload))
            files.append(fn)
            if event_sink:
                event_sink("CREAT", os.path.join(step_dir, fn))
        manifest["leaves"][key] = {
            "shape": list(a.shape), "dtype": str(a.dtype), "files": files,
        }
    with open(os.path.join(tmp_dir, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp_dir, step_dir)  # atomic publish
    if event_sink:
        event_sink("CLOSE", os.path.join(step_dir, "MANIFEST.json"))
    return step_dir


def load_checkpoint(ckpt_dir: str, abstract_tree, *, step: Optional[int] = None,
                    shardings=None):
    """Restore (optionally re-sharded onto a different mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    step_dir = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(step_dir, "MANIFEST.json")) as f:
        manifest = json.load(f)
    dec = zstd.ZstdDecompressor()
    flat_abs = _flatten(abstract_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_abs:
            continue
        parts = []
        for fn in meta["files"]:
            with open(os.path.join(step_dir, fn), "rb") as f:
                payload = msgpack.unpackb(dec.decompress(f.read()), raw=False)
            parts.append(np.frombuffer(payload["data"],
                                       np.dtype(payload["dtype"])
                                       ).reshape(payload["shape"]))
        a = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
        a = a.reshape(meta["shape"]).astype(np.dtype(meta["dtype"]))
        want = flat_abs[key]
        a = a.astype(want.dtype)
        if key in flat_sh and flat_sh[key] is not None:
            out[key] = jax.device_put(a, flat_sh[key])
        else:
            out[key] = jnp.asarray(a)
    missing = set(flat_abs) - set(out)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    return _unflatten_into(abstract_tree, out), manifest


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest COMPLETE step (manifest present) — partial writes skipped."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in os.listdir(ckpt_dir):
        m = re.match(r"step_(\d+)$", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "MANIFEST.json")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


class CheckpointManager:
    """keep_n retention + async saves + optional Icicle event emission."""

    def __init__(self, ckpt_dir: str, keep_n: int = 3,
                 event_sink: Optional[Callable] = None):
        self.dir = ckpt_dir
        self.keep_n = keep_n
        self.event_sink = event_sink
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save(self, step: int, tree, blocking: bool = True) -> None:
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now
        if blocking:
            save_checkpoint(self.dir, step, host_tree,
                            event_sink=self.event_sink)
            self.gc()
        else:
            if self._worker is None or not self._worker.is_alive():
                self._worker = threading.Thread(target=self._drain,
                                                daemon=True)
                self._worker.start()
            self._q.put((step, host_tree))

    def _drain(self):
        while True:
            try:
                step, tree = self._q.get(timeout=2.0)
            except queue.Empty:
                return
            save_checkpoint(self.dir, step, tree, event_sink=self.event_sink)
            self.gc()

    def wait(self):
        if self._worker is not None:
            self._worker.join(timeout=60)

    def restore(self, abstract_tree, shardings=None, step=None):
        return load_checkpoint(self.dir, abstract_tree, step=step,
                               shardings=shardings)

    def latest(self) -> Optional[int]:
        return latest_step(self.dir)

    def gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.dir)
            if (m := re.match(r"step_(\d+)$", d))
            and os.path.exists(os.path.join(self.dir, d, "MANIFEST.json")))
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)
        # incomplete tmp dirs from crashes
        for d in os.listdir(self.dir):
            if d.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)
