from repro.checkpoint.manager import (  # noqa: F401
    CheckpointManager, load_checkpoint, save_checkpoint)
