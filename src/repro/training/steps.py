"""train_step / eval_step factories.

``make_train_step(cfg, opt_cfg, mesh)`` returns a function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with the shardings from ``make_train_shardings``.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import models
from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models.layers import is_pd
from repro.training.losses import chunked_ce_loss
from repro.training.optimizer import (AdamWConfig, adamw_update,
                                      init_opt_state)

AUX_WEIGHT = 0.01  # MoE load-balance loss weight


def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict, mesh=None):
    compute_params = jax.tree.map(
        lambda p: p.astype(jnp.dtype(cfg.dtype)) if p.dtype == jnp.float32 else p,
        params)
    hidden, aux, _ = models.forward(cfg, compute_params, batch, mesh)
    ce = chunked_ce_loss(cfg, compute_params, hidden, batch["labels"])
    loss = ce + AUX_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


def _split_micro(cfg: ModelConfig, batch: Dict, n: int) -> Dict:
    """Reshape every batch array (B, ...) -> (n, B/n, ...). M-RoPE position
    ids carry a leading (3,) axis, so their batch dim is axis 1."""
    def split(key, x):
        ax = 1 if (key == "positions" and cfg.mrope_input) else 0
        b = x.shape[ax]
        assert b % n == 0, (key, b, n)
        new = x.shape[:ax] + (n, b // n) + x.shape[ax + 1:]
        x = x.reshape(new)
        return jnp.moveaxis(x, ax, 0)
    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, mesh=None):
    n_micro = max(cfg.microbatches, 1)

    # Constrain gradients to the zero1/fsdp-sharded layout at the point of
    # production: XLA then lowers the cross-data-replica combine as a
    # reduce-scatter (half the wire bytes of all-reduce + slice).
    grad_shardings = None
    if mesh is not None and (cfg.zero1 or cfg.fsdp):
        from repro import models as _models
        from repro.models.layers import is_pd
        desc = _models.param_desc(cfg)
        gspecs = jax.tree.map(
            lambda pd: shd.zero1_spec(pd.shape,
                                      shd.spec_for(pd, cfg, mesh), mesh),
            desc, is_leaf=is_pd)
        grad_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), gspecs)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(jax.lax.with_sharding_constraint, grads,
                            grad_shardings)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, parts), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, p, batch, mesh), has_aux=True)(params)
            grads = _constrain(grads)
        else:
            micro = _split_micro(cfg, batch, n_micro)

            def body(carry, mb):
                gsum, lsum, psum_ = carry
                (l, parts), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, p, mb, mesh), has_aux=True)(params)
                g = _constrain(g)
                gsum = jax.tree.map(jnp.add, gsum, g)
                psum_ = jax.tree.map(jnp.add, psum_, parts)
                return (gsum, lsum + l, psum_), None

            # Accumulate in the gradient's own dtype: bf16 master params give
            # bf16 grads (low-mem recipe for 300B-class models); f32 otherwise.
            g0 = jax.tree.map(lambda p: jnp.zeros_like(
                p, jnp.bfloat16 if p.dtype == jnp.bfloat16 else jnp.float32),
                params)
            g0 = _constrain(g0)  # accumulate in the reduce-scattered layout
            p0 = {"ce": jnp.zeros((), jnp.float32),
                  "aux": jnp.zeros((), jnp.float32)}
            (grads, loss, parts), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32), p0), micro)
            inv = 1.0 / n_micro
            grads = jax.tree.map(lambda g: g * inv, grads)
            loss = loss * inv
            parts = jax.tree.map(lambda x: x * inv, parts)
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics
    return train_step


def make_train_shardings(cfg: ModelConfig, mesh) -> Tuple[Dict, Dict, Dict]:
    """(param_shardings, opt_shardings, batch_shardings)."""
    desc = models.param_desc(cfg)
    pspecs = shd.param_specs(desc, cfg, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

    def opt_spec(pd, base):
        if cfg.zero1:
            return NamedSharding(mesh, shd.zero1_spec(pd.shape, base, mesh))
        return NamedSharding(mesh, base)

    mv = jax.tree.map(opt_spec, desc, pspecs, is_leaf=is_pd)
    osh = {"m": mv, "v": mv, "step": NamedSharding(mesh, P())}
    bsh = batch_shardings(cfg, mesh)
    return psh, osh, bsh


def batch_shardings(cfg: ModelConfig, mesh) -> Dict:
    dp = shd.dp_axes(mesh)
    out = {}
    if cfg.embeds_input:
        out["embeds"] = NamedSharding(mesh, P(dp, None, None))
    if not cfg.embeds_input or cfg.family == "audio":
        out["tokens"] = NamedSharding(mesh, P(dp, None))
    out["labels"] = NamedSharding(mesh, P(dp, None))
    if cfg.mrope_input:
        out["positions"] = NamedSharding(mesh, P(None, dp, None))
    else:
        out["positions"] = NamedSharding(mesh, P(dp, None))
    return out


def make_init_fns(cfg: ModelConfig):
    """Returns (init_params_fn, init_opt_fn) suitable for jit/eval_shape."""
    def init_p(key):
        return models.init_params(cfg, key)

    def init_o(params):
        return init_opt_state(params)
    return init_p, init_o
