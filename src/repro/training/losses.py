"""Sequence-chunked causal-LM cross-entropy.

The (B, S, V) logits tensor never materializes: we scan over sequence
chunks, computing bf16 logits + f32 log-sum-exp per chunk. With a
model-sharded vocab the LSE reduce becomes one small all-reduce per chunk.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import softcap


def chunked_ce_loss(cfg: ModelConfig, params: Dict, hidden: jax.Array,
                    labels: jax.Array, mask: jax.Array = None) -> jax.Array:
    """hidden: (B,S,d); labels: (B,S) int32 (-1 = ignore)."""
    b, s, d = hidden.shape
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    c = min(cfg.loss_chunk, s)
    assert s % c == 0
    n = s // c
    hc = hidden.reshape(b, n, c, d).swapaxes(0, 1)          # (n,b,c,d)
    lc = labels.reshape(b, n, c).swapaxes(0, 1)
    if mask is None:
        mask = (labels >= 0)
    mc = mask.reshape(b, n, c).swapaxes(0, 1)

    def chunk(carry, inp):
        tot, cnt = carry
        h, lab, m = inp
        logits = jnp.einsum("bcd,dv->bcv", h, head.astype(h.dtype))
        logits = softcap(logits, cfg.logit_softcap).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lab, 0)[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return (tot + jnp.sum(nll), cnt + jnp.sum(m)), None

    # Remat the chunk body: otherwise backward saves every chunk's logits,
    # reconstituting the full (B,S,V) tensor the chunking exists to avoid.
    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc.astype(jnp.float32)))
    return tot / jnp.maximum(cnt, 1.0)
