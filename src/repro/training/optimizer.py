"""AdamW with optional ZeRO-1 optimizer-state sharding and gradient
compression hooks.

Pure-pytree implementation (no optax dependency): state is {m, v, step}.
``zero1=True`` re-shards m/v over the "data" mesh axis (see
``distributed.sharding.zero1_spec``) — on a 1000+-node deployment this is
what keeps 300B-param optimizer state within per-chip HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # Moment storage dtype. "bfloat16" halves optimizer HBM (the compute is
    # still f32); required to fit 300B-class models on 16 GiB chips.
    moment_dtype: str = "float32"


def lr_at(c: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(c.warmup_steps, 1)
    prog = jnp.clip((step - c.warmup_steps) /
                    jnp.maximum(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    decay = c.min_lr_ratio + (1 - c.min_lr_ratio) * cos
    return c.lr * jnp.where(step < c.warmup_steps, warm, decay)


def init_opt_state(params, moment_dtype: str = "float32") -> Dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.dtype(moment_dtype))
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(c: AdamWConfig, params, grads, state) -> Tuple[Any, Dict, Dict]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(c, step)
    b1c = 1 - c.b1 ** step.astype(jnp.float32)
    b2c = 1 - c.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = c.b1 * m.astype(jnp.float32) + (1 - c.b1) * g
        v_new = c.b2 * v.astype(jnp.float32) + (1 - c.b2) * g * g
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + c.eps) + c.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics


# ---------------------------------------------------------------------------
# Gradient compression (distributed-optimization trick; optional)
# ---------------------------------------------------------------------------

def compress_grads_int8(grads):
    """Per-tensor symmetric int8 quantization with f32 scale (for low-
    bandwidth all-reduce). Returns (q_tree, scale_tree)."""
    def q(g):
        a = jnp.max(jnp.abs(g)).astype(jnp.float32)
        s = jnp.maximum(a, 1e-12) / 127.0
        return jnp.clip(jnp.round(g / s), -127, 127).astype(jnp.int8), s
    out = jax.tree.map(q, grads)
    qt = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    st = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return qt, st


def decompress_grads_int8(qt, st):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, qt, st)
