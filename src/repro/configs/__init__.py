from repro.configs.base import (  # noqa: F401
    SHAPES,
    EncDecConfig,
    HybridConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_config,
    list_archs,
    shape_applicable,
)
