"""RecurrentGemma-2B [arXiv:2402.19427]: 26L d_model=2560 10H (MQA kv=1)
d_ff=7680 vocab=256000 — RG-LRU recurrent blocks + local attention (window
2048) in a 2:1 pattern. Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig, HybridConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="gelu_tanh",
    gated_mlp=True,
    tie_embeddings=True,
    embedding_scale=True,
    hybrid=HybridConfig(
        pattern=("rglru", "rglru", "local_attn"),
        lru_width=2560,
        window=2048,
        conv_width=4,
    ),
    subquadratic=True,
    microbatches=4,
))
