"""Typed model / run configuration for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`. The
config is deliberately explicit (no HF-style inheritance magic): each field
is consumed by exactly one model-family builder in ``repro.models``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Enums (plain strings; validated in __post_init__)
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
ROPE_VARIANTS = ("none", "rope", "rope2d", "mrope", "learned_abs")
NORMS = ("rmsnorm", "layernorm", "nonparametric_ln")
ACTIVATIONS = ("silu", "gelu", "gelu_tanh")
ATTN_KINDS = ("full", "local")
MOE_SHARDINGS = ("ep", "tp")


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (DeepSeek-MoE / Grok style)."""

    num_experts: int = 0              # routed experts
    top_k: int = 0
    d_ff_expert: int = 0              # per-expert FFN hidden dim
    num_shared_experts: int = 0       # always-on experts (DeepSeek fine-grained)
    # Layers that use a plain dense FFN instead of MoE (DeepSeek-MoE layer 0).
    dense_layers: Tuple[int, ...] = ()
    dense_layer_d_ff: int = 0
    # Router options
    router_softmax_order: str = "topk_then_softmax"  # or "softmax_then_topk"
    capacity_factor: float = 1.25
    # How expert weights shard over the "model" mesh axis:
    #   "ep": expert dim sharded (requires num_experts % model_axis == 0)
    #   "tp": per-expert FFN hidden dim sharded (megatron-style)
    sharding: str = "ep"


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) sub-config."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64                # SSD head dim (nheads = d_inner // head_dim)
    n_groups: int = 1
    chunk_size: int = 256             # SSD block-decomposition chunk


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid (RG-LRU + local attention)."""

    # Repeating block pattern, e.g. ("rglru", "rglru", "local_attn").
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    lru_width: int = 0                # 0 -> d_model
    window: int = 2048                # local attention window
    conv_width: int = 4               # temporal conv inside recurrent block


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (Whisper) sub-config."""

    encoder_layers: int = 0
    max_source_positions: int = 0     # encoder frame positions (learned abs)


@dataclass(frozen=True)
class ModelConfig:
    # -- identity ----------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"
    # -- trunk dimensions ---------------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0
    # -- flavour knobs -------------------------------------------------------
    rope: str = "rope"
    rope_theta: float = 10000.0
    # M-RoPE sections (temporal, height, width) in head_dim/2 units.
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    qkv_bias: bool = False
    norm: str = "rmsnorm"
    norm_eps: float = 1e-5
    activation: str = "silu"
    gated_mlp: bool = True            # SwiGLU/GeGLU vs plain 2-layer MLP
    tie_embeddings: bool = False
    logit_softcap: float = 0.0        # grok/gemma-style tanh soft-capping
    attn_logit_softcap: float = 0.0
    embedding_scale: bool = False     # multiply embeddings by sqrt(d_model)
    # -- sub-configs ---------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # -- execution knobs ------------------------------------------------------
    dtype: str = "bfloat16"           # compute dtype
    param_dtype: str = "float32"      # master param dtype
    scan_layers: bool = True
    remat: bool = True
    remat_policy: str = "nothing_saveable"
    attn_chunk_q: int = 1024          # flash-style chunking of the query dim
    attn_chunk_kv: int = 1024
    loss_chunk: int = 2048            # sequence chunking of the CE loss
    zero1: bool = False               # shard optimizer state over "data"
    fsdp: bool = False                # shard params over "data" too (ZeRO-3)
    microbatches: int = 1             # gradient-accumulation chunks
    # Whether full (non-windowed) attention makes long_500k tractable.
    subquadratic: bool = False
    # Modality frontend stub: inputs are precomputed embeddings, not token ids.
    embeds_input: bool = False
    # M-RoPE position ids have a leading (3,) axis.
    mrope_input: bool = False

    # -- derived -------------------------------------------------------------
    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert self.rope in ROPE_VARIANTS, self.rope
        assert self.norm in NORMS, self.norm
        assert self.activation in ACTIVATIONS, self.activation
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def kv_groups(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Approximate dense parameter count N (for 6ND model-FLOPs)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.head_dim
        nh, nkv = self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            nheads = d_in // s.head_dim
            per = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)  # in_proj
                + (d_in + 2 * s.n_groups * s.d_state) * s.d_conv      # conv
                + nheads * 2                                           # A, D
                + d_in                                                 # norm-ish
                + d_in * d                                             # out_proj
            )
            return emb + L * per
        attn = d * (nh * hd) + d * (2 * nkv * hd) + (nh * hd) * d
        mlp_mult = 3 if self.gated_mlp else 2
        if self.family == "moe":
            m = self.moe
            n_moe = L - len(m.dense_layers)
            moe_mlp = (m.num_experts + m.num_shared_experts) * mlp_mult * d * m.d_ff_expert
            moe_mlp += d * m.num_experts  # router
            dense_mlp = mlp_mult * d * (m.dense_layer_d_ff or self.d_ff)
            return emb + L * attn + n_moe * moe_mlp + len(m.dense_layers) * dense_mlp
        if self.family == "hybrid":
            h = self.hybrid
            w = h.lru_width or d
            n_att = sum(1 for i in range(L) if h.pattern[i % len(h.pattern)] == "local_attn")
            n_rec = L - n_att
            rec = d * w * 2 + w * h.conv_width + w * 4 + w * d  # in/out proj + gates
            return emb + n_att * (attn + mlp_mult * d * self.d_ff) + n_rec * (rec + mlp_mult * d * self.d_ff)
        if self.family == "audio":
            e = self.encdec
            enc = e.encoder_layers * (attn + mlp_mult * d * self.d_ff)
            dec = L * (attn * 2 + mlp_mult * d * self.d_ff)  # self + cross attn
            return emb + enc + dec
        return emb + L * (attn + mlp_mult * d * self.d_ff)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k count)."""
        if self.family != "moe":
            return self.param_count()
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd, nh, nkv = self.head_dim, self.num_heads, self.num_kv_heads
        m = self.moe
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (nh * hd) + d * (2 * nkv * hd) + (nh * hd) * d
        mlp_mult = 3 if self.gated_mlp else 2
        n_moe = L - len(m.dense_layers)
        act_mlp = (m.top_k + m.num_shared_experts) * mlp_mult * d * m.d_ff_expert
        dense_mlp = mlp_mult * d * (m.dense_layer_d_ff or self.d_ff)
        return emb + L * attn + n_moe * act_mlp + len(m.dense_layers) * dense_mlp

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len x global_batch).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch x shape) cell runs; reason string if skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; %s is full-attention" % cfg.name
    return True, ""


# Registry filled by the per-arch modules.
_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs.all_archs  # noqa: F401  (populates registry)

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> Sequence[str]:
    import repro.configs.all_archs  # noqa: F401

    return sorted(_REGISTRY)
