"""DeepSeek-Coder-33B [arXiv:2401.14196]: 62L d_model=7168 56H (GQA kv=8)
d_ff=19200 vocab=32256 — llama architecture (RoPE, RMSNorm, SwiGLU)."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
    rope="rope",
    rope_theta=100000.0,
    qkv_bias=False,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    zero1=True,
    fsdp=True,
    microbatches=16,
))
