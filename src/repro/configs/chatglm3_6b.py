"""ChatGLM3-6B [arXiv:2406.12793]: 28L d_model=4096 32H (GQA kv=2)
d_ff=13696 vocab=65024 — 2D RoPE (applied to half the head dim), QKV bias,
RMSNorm, SwiGLU."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope="rope2d",
    rope_theta=10000.0,
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    zero1=True,
    microbatches=4,
))
