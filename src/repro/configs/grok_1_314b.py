"""Grok-1-314B [hf:xai-org/grok-1]: 64L d_model=6144 48H (GQA kv=8)
d_ff=32768 vocab=131072 — MoE 8 experts top-2, gelu MLP, attention/output
logit soft-capping, embedding scaling."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="gelu",
    gated_mlp=True,
    logit_softcap=30.0,
    attn_logit_softcap=30.0,
    embedding_scale=True,
    moe=MoEConfig(
        num_experts=8,
        top_k=2,
        d_ff_expert=32768,
        num_shared_experts=0,
        sharding="tp",          # 8 experts < model axis 16 -> megatron-style
    ),
    zero1=True,
    fsdp=True,
    microbatches=8,
    # 314B params: f32 master + f32 moments = 5 TB of state, which cannot
    # fit 256 x 16 GiB even perfectly sharded. Low-mem recipe: bf16 master
    # params, bf16 Adam moments (f32 compute), bf16 grad accumulation.
    param_dtype="bfloat16",
))
