"""OLMo-1B [arXiv:2402.00838]: 16L d_model=2048 16H (MHA) d_ff=8192
vocab=50304 — non-parametric LayerNorm, RoPE, SwiGLU, no biases."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    rope="rope",
    rope_theta=10000.0,
    qkv_bias=False,
    norm="nonparametric_ln",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
))
