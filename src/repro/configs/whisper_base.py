"""Whisper-base [arXiv:2212.04356]: enc-dec, 6L decoder (+6L encoder)
d_model=512 8H (MHA) d_ff=2048 vocab=51865 — learned absolute positions,
parametric LayerNorm, gelu MLP (non-gated). The conv audio frontend is a
STUB: input_specs() provides precomputed frame embeddings."""
from repro.configs.base import EncDecConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                 # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    rope="learned_abs",
    qkv_bias=True,                # whisper uses biased q/v projections
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    tie_embeddings=True,
    encdec=EncDecConfig(encoder_layers=6, max_source_positions=32768),
    embeds_input=True,
    microbatches=4,
))
