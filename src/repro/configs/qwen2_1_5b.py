"""Qwen2-1.5B [arXiv:2407.10671]: 28L d_model=1536 12H (GQA kv=2)
d_ff=8960 vocab=151936 — GQA, QKV bias, RoPE theta=1e6, tied embeddings."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    rope="rope",
    rope_theta=1000000.0,
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    microbatches=2,
))
