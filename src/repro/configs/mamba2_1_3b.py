"""Mamba2-1.3B [arXiv:2405.21060]: 48L d_model=2048 attention-free,
vocab=50280, ssm_state=128 — SSD (state-space duality) with chunked
block-decomposition. Sub-quadratic: runs long_500k."""
from repro.configs.base import ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    rope="none",
    norm="rmsnorm",
    gated_mlp=False,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    subquadratic=True,
    zero1=True,
    microbatches=4,
))
