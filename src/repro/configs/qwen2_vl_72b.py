"""Qwen2-VL-72B [arXiv:2409.12191]: 80L d_model=8192 64H (GQA kv=8)
d_ff=29568 vocab=152064 — M-RoPE (multimodal 3-section rotary), dynamic
resolution. The vision tower is a STUB: input_specs() provides precomputed
patch embeddings merged into the token stream plus (3, B, S) M-RoPE ids."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    rope="mrope",
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    embeds_input=True,
    mrope_input=True,
    zero1=True,
    fsdp=True,
    microbatches=16,
))
