"""DeepSeek-MoE-16B [arXiv:2401.06066]: 28L d_model=2048 16H (MHA)
d_ff_expert=1408 vocab=102400 — fine-grained MoE: 64 routed experts top-6 +
2 shared experts; layer 0 uses a dense FFN (d_ff=10944)."""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    rope="rope",
    rope_theta=10000.0,
    norm="rmsnorm",
    activation="silu",
    gated_mlp=True,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared_experts=2,
        dense_layers=(0,),
        dense_layer_d_ff=10944,
        router_softmax_order="softmax_then_topk",
        sharding="ep",          # 64 experts shard cleanly over model=16
    ),
    zero1=True,
    fsdp=True,
    microbatches=4,
))
