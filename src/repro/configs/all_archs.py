"""Import side-effect module that populates the arch registry."""
import repro.configs.olmo_1b  # noqa: F401
import repro.configs.chatglm3_6b  # noqa: F401
import repro.configs.qwen2_1_5b  # noqa: F401
import repro.configs.deepseek_coder_33b  # noqa: F401
import repro.configs.mamba2_1_3b  # noqa: F401
import repro.configs.deepseek_moe_16b  # noqa: F401
import repro.configs.grok_1_314b  # noqa: F401
import repro.configs.recurrentgemma_2b  # noqa: F401
import repro.configs.qwen2_vl_72b  # noqa: F401
import repro.configs.whisper_base  # noqa: F401

ARCH_IDS = (
    "olmo-1b",
    "chatglm3-6b",
    "qwen2-1.5b",
    "deepseek-coder-33b",
    "mamba2-1.3b",
    "deepseek-moe-16b",
    "grok-1-314b",
    "recurrentgemma-2b",
    "qwen2-vl-72b",
    "whisper-base",
)
